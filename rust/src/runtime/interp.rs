//! The pure-Rust execution substrate of the default [`CpuBackend`]: a tiny
//! static-shape tensor IR covering the op set the AOT graphs lower to
//! (dot/matmul, elementwise arithmetic, exp/tanh/rsqrt, reductions,
//! broadcast/reshape/transpose, select-style masking, iota, gather/scatter)
//! plus an interpreter that executes a [`Graph`] against name-bound feeds.
//!
//! Semantics mirror `python/compile/kernels/ref.py` / `jax.numpy`:
//! row-major tensors, numpy-style right-aligned broadcasting, f32 compute.
//! Shapes are fully static and inferred at graph-construction time, so
//! every kernel below runs without per-element shape checks.
//!
//! Execution is driven by a once-per-executable [`ExecPlan`] (last-use free
//! lists, in-place donors, precomputed broadcast/transpose strides), a
//! size-keyed buffer [`Arena`] that recycles dying values, and the blocked
//! multi-threaded matmul kernels in [`crate::kernels`]. Owned inputs
//! ([`Arg::OwnF32`]) may be consumed in place — the decode KV-cache update
//! mutates its cache buffer instead of cloning it.
//!
//! [`CpuBackend`]: super::cpu::CpuBackend

use std::collections::HashMap;
use std::sync::OnceLock;

use crate::kernels;
use crate::quant::PackedInt8;
use crate::runtime::exec::{Feed, Value};
use crate::runtime::fusion::{plan_fusion, FusedOp, FusionPlan};
use crate::tensor::{IntTensor, Tensor};
use crate::Result;

/// Node id inside one [`Graph`] (ids are topologically ordered by
/// construction: every operand id is smaller than its consumer's).
pub type Id = usize;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    /// Packed int8 weights (per-group scales). Input-only: produced by no
    /// op, consumed only by [`Op::MatmulQ`].
    Q8,
}

/// One IR operation. Structural parameters (shapes, axes, permutations)
/// are baked in; tensor operands are node ids.
#[derive(Debug, Clone)]
pub enum Op {
    /// Placeholder bound to manifest input `k` at execution time.
    Input(usize),
    /// Baked constant (causal masks, rope frequency tables, scalars).
    Const(Value),

    // ---- unary (f32) ----
    Neg(Id),
    Exp(Id),
    Log(Id),
    Sqrt(Id),
    Rsqrt(Id),
    Tanh(Id),
    Sigmoid(Id),
    Cos(Id),
    Sin(Id),
    /// Identity forward; blocks gradient flow (softmax/logsumexp shifts).
    StopGrad(Id),
    /// i32 → f32 cast (positions, lengths).
    CastF32(Id),

    // ---- binary with numpy broadcasting (f32) ----
    Add(Id, Id),
    Sub(Id, Id),
    Mul(Id, Id),
    Div(Id, Id),
    Maximum(Id, Id),
    /// 1.0 where a < b else 0.0 (mask construction).
    Less(Id, Id),

    // ---- contractions ----
    /// 2-D matmul with transpose flags: C = op(A) · op(B).
    Matmul { a: Id, b: Id, ta: bool, tb: bool },
    /// Quantized 2-D matmul: C = X · Wᵀ with `x` f32 (m, k) and `w` packed
    /// int8 stored (n, k) — weights stay packed, accumulation in f32
    /// (serving-only, no VJP).
    MatmulQ { x: Id, w: Id },
    /// Batched 3-D matmul over the leading dim.
    Bmm { a: Id, b: Id, ta: bool, tb: bool },

    // ---- structure ----
    Reshape(Id, Vec<usize>),
    Transpose(Id, Vec<usize>),
    /// Numpy-broadcast to an explicit shape.
    Broadcast(Id, Vec<usize>),
    Concat(Vec<Id>, usize),
    Slice { x: Id, axis: usize, start: usize, len: usize },
    /// Embed into zeros along `axis` at `start` (adjoint of `Slice`; also
    /// the static prefill KV-cache write).
    PadZero { x: Id, axis: usize, start: usize, full: usize },

    // ---- reductions (single axis, no keepdims) ----
    ReduceSum(Id, usize),
    ReduceMax(Id, usize),

    // ---- indexing ----
    /// out[j, :] = table[idx[j], :] — embedding lookup.
    Gather { table: Id, idx: Id },
    /// out[j] = x[j, idx[j]] over the last axis — target-logit pick.
    TakeLast { x: Id, idx: Id },
    /// Adjoint of `Gather`: rows of `upd` summed into zeros[rows, d].
    ScatterAddRows { idx: Id, upd: Id, rows: usize },
    /// Adjoint of `TakeLast`: upd[j] written at [j, idx[j]] in zeros[.., n].
    ScatterLast { idx: Id, upd: Id, n: usize },
    /// KV-cache write: cache (b,h,s,d) ← kv (b,h,d) at per-batch position
    /// pos (b,) — the decode-step dynamic-update-slice.
    UpdateAt { cache: Id, kv: Id, pos: Id },
    /// Row write into a 2-D table: table (R, D) ← upd (b, D) at per-batch
    /// row pos (b,). The paged-KV pool write (rows are token slots of the
    /// block pool); duplicate positions resolve to the highest batch index.
    UpdateRows { table: Id, upd: Id, pos: Id },
    /// Block-table gather over a paged KV pool: pool (R, heads·dh) with
    /// R = num_blocks·block_len rows, idx (b, nblk) i32 block ids →
    /// out (b, heads, nblk·block_len, dh) — the per-request attention
    /// window, reassembled from scattered blocks.
    GatherBlocks { pool: Id, idx: Id, block_len: usize, heads: usize },
    /// f32 ramp [0, len).
    Iota { len: usize },
}

impl Op {
    /// Tensor operand ids, in order.
    pub fn operands(&self) -> Vec<Id> {
        match self {
            Op::Input(_) | Op::Const(_) | Op::Iota { .. } => vec![],
            Op::Neg(x)
            | Op::Exp(x)
            | Op::Log(x)
            | Op::Sqrt(x)
            | Op::Rsqrt(x)
            | Op::Tanh(x)
            | Op::Sigmoid(x)
            | Op::Cos(x)
            | Op::Sin(x)
            | Op::StopGrad(x)
            | Op::CastF32(x)
            | Op::Reshape(x, _)
            | Op::Transpose(x, _)
            | Op::Broadcast(x, _)
            | Op::Slice { x, .. }
            | Op::PadZero { x, .. }
            | Op::ReduceSum(x, _)
            | Op::ReduceMax(x, _) => vec![*x],
            Op::Add(a, b)
            | Op::Sub(a, b)
            | Op::Mul(a, b)
            | Op::Div(a, b)
            | Op::Maximum(a, b)
            | Op::Less(a, b)
            | Op::Matmul { a, b, .. }
            | Op::Bmm { a, b, .. } => vec![*a, *b],
            Op::MatmulQ { x, w } => vec![*x, *w],
            Op::Concat(xs, _) => xs.clone(),
            Op::Gather { table, idx } => vec![*table, *idx],
            Op::TakeLast { x, idx } => vec![*x, *idx],
            Op::ScatterAddRows { idx, upd, .. } => vec![*idx, *upd],
            Op::ScatterLast { idx, upd, .. } => vec![*idx, *upd],
            Op::UpdateAt { cache, kv, pos } => vec![*cache, *kv, *pos],
            Op::UpdateRows { table, upd, pos } => vec![*table, *upd, *pos],
            Op::GatherBlocks { pool, idx, .. } => vec![*pool, *idx],
        }
    }
}

#[derive(Debug, Clone)]
pub struct Node {
    pub op: Op,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// A static-shape computation graph under construction / execution.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    /// Number of declared inputs (Input(k) for k < n_inputs).
    pub n_inputs: usize,
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Numpy broadcast of two shapes (right-aligned), or None if incompatible.
pub fn broadcast_shapes(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let r = a.len().max(b.len());
    let mut out = vec![0usize; r];
    for i in 0..r {
        let da = if i < r - a.len() { 1 } else { a[i - (r - a.len())] };
        let db = if i < r - b.len() { 1 } else { b[i - (r - b.len())] };
        if da == db || da == 1 || db == 1 {
            out[i] = da.max(db);
        } else {
            return None;
        }
    }
    Some(out)
}

impl Graph {
    pub fn shape(&self, id: Id) -> &[usize] {
        &self.nodes[id].shape
    }

    pub fn dtype(&self, id: Id) -> DType {
        self.nodes[id].dtype
    }

    fn push(&mut self, op: Op, shape: Vec<usize>, dtype: DType) -> Id {
        self.nodes.push(Node { op, shape, dtype });
        self.nodes.len() - 1
    }

    // ---------------- construction API ----------------

    /// Declare the next manifest input (call in manifest order).
    pub fn input(&mut self, shape: &[usize], dtype: DType) -> Id {
        let k = self.n_inputs;
        self.n_inputs += 1;
        self.push(Op::Input(k), shape.to_vec(), dtype)
    }

    pub fn constant(&mut self, t: Tensor) -> Id {
        let shape = t.shape.clone();
        self.push(Op::Const(Value::F32(t)), shape, DType::F32)
    }

    pub fn scalar(&mut self, v: f32) -> Id {
        self.constant(Tensor::from_vec(&[], vec![v]))
    }

    pub fn constant_i32(&mut self, t: IntTensor) -> Id {
        let shape = t.shape.clone();
        self.push(Op::Const(Value::I32(t)), shape, DType::I32)
    }

    fn unary(&mut self, f: impl Fn(Id) -> Op, x: Id) -> Id {
        assert_eq!(self.dtype(x), DType::F32, "unary op on non-f32 node {x}");
        let shape = self.shape(x).to_vec();
        self.push(f(x), shape, DType::F32)
    }

    pub fn neg(&mut self, x: Id) -> Id {
        self.unary(Op::Neg, x)
    }
    pub fn exp(&mut self, x: Id) -> Id {
        self.unary(Op::Exp, x)
    }
    pub fn log(&mut self, x: Id) -> Id {
        self.unary(Op::Log, x)
    }
    pub fn sqrt(&mut self, x: Id) -> Id {
        self.unary(Op::Sqrt, x)
    }
    pub fn rsqrt(&mut self, x: Id) -> Id {
        self.unary(Op::Rsqrt, x)
    }
    pub fn tanh(&mut self, x: Id) -> Id {
        self.unary(Op::Tanh, x)
    }
    pub fn sigmoid(&mut self, x: Id) -> Id {
        self.unary(Op::Sigmoid, x)
    }
    pub fn cos(&mut self, x: Id) -> Id {
        self.unary(Op::Cos, x)
    }
    pub fn sin(&mut self, x: Id) -> Id {
        self.unary(Op::Sin, x)
    }
    pub fn stop_grad(&mut self, x: Id) -> Id {
        self.unary(Op::StopGrad, x)
    }

    pub fn cast_f32(&mut self, x: Id) -> Id {
        let shape = self.shape(x).to_vec();
        self.push(Op::CastF32(x), shape, DType::F32)
    }

    fn binary(&mut self, f: impl Fn(Id, Id) -> Op, a: Id, b: Id) -> Id {
        assert_eq!(self.dtype(a), DType::F32, "binary op lhs must be f32");
        assert_eq!(self.dtype(b), DType::F32, "binary op rhs must be f32");
        let shape = broadcast_shapes(self.shape(a), self.shape(b)).unwrap_or_else(|| {
            panic!("broadcast mismatch: {:?} vs {:?}", self.shape(a), self.shape(b))
        });
        self.push(f(a, b), shape, DType::F32)
    }

    pub fn add(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Add, a, b)
    }
    pub fn sub(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Sub, a, b)
    }
    pub fn mul(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Mul, a, b)
    }
    pub fn div(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Div, a, b)
    }
    pub fn maximum(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Maximum, a, b)
    }
    pub fn less(&mut self, a: Id, b: Id) -> Id {
        self.binary(Op::Less, a, b)
    }

    pub fn matmul(&mut self, a: Id, b: Id, ta: bool, tb: bool) -> Id {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 2, "matmul lhs must be 2-D, got {sa:?}");
        assert_eq!(sb.len(), 2, "matmul rhs must be 2-D, got {sb:?}");
        let (m, ka) = if ta { (sa[1], sa[0]) } else { (sa[0], sa[1]) };
        let (kb, n) = if tb { (sb[1], sb[0]) } else { (sb[0], sb[1]) };
        assert_eq!(ka, kb, "matmul inner dim: {sa:?} (ta={ta}) vs {sb:?} (tb={tb})");
        self.push(Op::Matmul { a, b, ta, tb }, vec![m, n], DType::F32)
    }

    /// Quantized matmul against packed int8 weights: `x` f32 (m, k) times
    /// the transpose of `w` Q8 stored (n, k) → f32 (m, n). The stored
    /// layout matches the serving convention for both SVD factors
    /// (`y = x · Wᵀ`), with quantization groups along the dot dimension.
    pub fn matmul_q(&mut self, x: Id, w: Id) -> Id {
        let (sx, sw) = (self.shape(x).to_vec(), self.shape(w).to_vec());
        assert_eq!(self.dtype(x), DType::F32, "matmul_q lhs must be f32");
        assert_eq!(self.dtype(w), DType::Q8, "matmul_q rhs must be q8");
        assert_eq!(sx.len(), 2, "matmul_q lhs must be 2-D, got {sx:?}");
        assert_eq!(sw.len(), 2, "matmul_q rhs must be 2-D, got {sw:?}");
        assert_eq!(sx[1], sw[1], "matmul_q inner dim: {sx:?} vs {sw:?} (stored (n, k))");
        self.push(Op::MatmulQ { x, w }, vec![sx[0], sw[0]], DType::F32)
    }

    pub fn bmm(&mut self, a: Id, b: Id, ta: bool, tb: bool) -> Id {
        let (sa, sb) = (self.shape(a).to_vec(), self.shape(b).to_vec());
        assert_eq!(sa.len(), 3, "bmm lhs must be 3-D, got {sa:?}");
        assert_eq!(sb.len(), 3, "bmm rhs must be 3-D, got {sb:?}");
        assert_eq!(sa[0], sb[0], "bmm batch dims differ");
        let (m, ka) = if ta { (sa[2], sa[1]) } else { (sa[1], sa[2]) };
        let (kb, n) = if tb { (sb[2], sb[1]) } else { (sb[1], sb[2]) };
        assert_eq!(ka, kb, "bmm inner dim: {sa:?} (ta={ta}) vs {sb:?} (tb={tb})");
        self.push(Op::Bmm { a, b, ta, tb }, vec![sa[0], m, n], DType::F32)
    }

    pub fn reshape(&mut self, x: Id, shape: &[usize]) -> Id {
        assert_eq!(
            numel(self.shape(x)),
            numel(shape),
            "reshape {:?} -> {shape:?}",
            self.shape(x)
        );
        let dt = self.dtype(x);
        self.push(Op::Reshape(x, shape.to_vec()), shape.to_vec(), dt)
    }

    pub fn transpose(&mut self, x: Id, perm: &[usize]) -> Id {
        let s = self.shape(x).to_vec();
        assert_eq!(perm.len(), s.len(), "transpose perm rank");
        let mut seen = vec![false; s.len()];
        for &p in perm {
            assert!(!seen[p], "transpose perm not a permutation");
            seen[p] = true;
        }
        let shape: Vec<usize> = perm.iter().map(|&p| s[p]).collect();
        let dt = self.dtype(x);
        self.push(Op::Transpose(x, perm.to_vec()), shape, dt)
    }

    pub fn broadcast(&mut self, x: Id, shape: &[usize]) -> Id {
        let got = broadcast_shapes(self.shape(x), shape).unwrap_or_else(|| {
            panic!("cannot broadcast {:?} to {shape:?}", self.shape(x))
        });
        assert_eq!(got, shape, "broadcast of {:?} to {shape:?} would grow", self.shape(x));
        self.push(Op::Broadcast(x, shape.to_vec()), shape.to_vec(), DType::F32)
    }

    pub fn concat(&mut self, xs: &[Id], axis: usize) -> Id {
        assert!(!xs.is_empty());
        let mut shape = self.shape(xs[0]).to_vec();
        for &x in &xs[1..] {
            let s = self.shape(x);
            assert_eq!(s.len(), shape.len(), "concat rank");
            for (d, (&a, &b)) in shape.iter().zip(s.iter()).enumerate() {
                if d != axis {
                    assert_eq!(a, b, "concat non-axis dims must match");
                }
            }
            shape[axis] += s[axis];
        }
        self.push(Op::Concat(xs.to_vec(), axis), shape, DType::F32)
    }

    pub fn slice(&mut self, x: Id, axis: usize, start: usize, len: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(start + len <= shape[axis], "slice out of range");
        shape[axis] = len;
        self.push(Op::Slice { x, axis, start, len }, shape, DType::F32)
    }

    pub fn pad_zero(&mut self, x: Id, axis: usize, start: usize, full: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(start + shape[axis] <= full, "pad_zero out of range");
        shape[axis] = full;
        self.push(Op::PadZero { x, axis, start, full }, shape, DType::F32)
    }

    pub fn reduce_sum(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(axis < shape.len());
        shape.remove(axis);
        self.push(Op::ReduceSum(x, axis), shape, DType::F32)
    }

    pub fn reduce_max(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        assert!(axis < shape.len());
        shape.remove(axis);
        self.push(Op::ReduceMax(x, axis), shape, DType::F32)
    }

    /// Reduce-sum keeping the axis as size 1 (keepdims=True).
    pub fn reduce_sum_keep(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        let r = self.reduce_sum(x, axis);
        shape[axis] = 1;
        self.reshape(r, &shape)
    }

    pub fn reduce_max_keep(&mut self, x: Id, axis: usize) -> Id {
        let mut shape = self.shape(x).to_vec();
        let r = self.reduce_max(x, axis);
        shape[axis] = 1;
        self.reshape(r, &shape)
    }

    pub fn gather(&mut self, table: Id, idx: Id) -> Id {
        assert_eq!(self.shape(table).len(), 2, "gather table must be 2-D");
        assert_eq!(self.dtype(idx), DType::I32, "gather index must be i32");
        let d = self.shape(table)[1];
        let mut shape = self.shape(idx).to_vec();
        shape.push(d);
        self.push(Op::Gather { table, idx }, shape, DType::F32)
    }

    pub fn take_last(&mut self, x: Id, idx: Id) -> Id {
        let sx = self.shape(x).to_vec();
        assert!(!sx.is_empty());
        assert_eq!(self.dtype(idx), DType::I32, "take_last index must be i32");
        assert_eq!(&sx[..sx.len() - 1], self.shape(idx), "take_last index shape");
        self.push(Op::TakeLast { x, idx }, sx[..sx.len() - 1].to_vec(), DType::F32)
    }

    pub fn scatter_add_rows(&mut self, idx: Id, upd: Id, rows: usize) -> Id {
        let su = self.shape(upd).to_vec();
        let d = *su.last().expect("scatter_add_rows upd rank");
        assert_eq!(&su[..su.len() - 1], self.shape(idx), "scatter_add_rows shapes");
        self.push(Op::ScatterAddRows { idx, upd, rows }, vec![rows, d], DType::F32)
    }

    pub fn scatter_last(&mut self, idx: Id, upd: Id, n: usize) -> Id {
        assert_eq!(self.shape(idx), self.shape(upd), "scatter_last shapes");
        let mut shape = self.shape(upd).to_vec();
        shape.push(n);
        self.push(Op::ScatterLast { idx, upd, n }, shape, DType::F32)
    }

    pub fn update_at(&mut self, cache: Id, kv: Id, pos: Id) -> Id {
        let sc = self.shape(cache).to_vec();
        let sk = self.shape(kv);
        assert_eq!(sc.len(), 4, "update_at cache must be (b,h,s,d)");
        assert_eq!(sk, &[sc[0], sc[1], sc[3]][..], "update_at kv shape");
        assert_eq!(self.shape(pos), &[sc[0]][..], "update_at pos shape");
        assert_eq!(self.dtype(pos), DType::I32);
        self.push(Op::UpdateAt { cache, kv, pos }, sc, DType::F32)
    }

    pub fn update_rows(&mut self, table: Id, upd: Id, pos: Id) -> Id {
        let st = self.shape(table).to_vec();
        assert_eq!(st.len(), 2, "update_rows table must be 2-D (rows, d)");
        assert_eq!(
            self.shape(upd),
            &[self.shape(pos)[0], st[1]][..],
            "update_rows upd shape"
        );
        assert_eq!(self.shape(pos).len(), 1, "update_rows pos must be (b,)");
        assert_eq!(self.dtype(pos), DType::I32);
        self.push(Op::UpdateRows { table, upd, pos }, st, DType::F32)
    }

    pub fn gather_blocks(&mut self, pool: Id, idx: Id, block_len: usize, heads: usize) -> Id {
        let sp = self.shape(pool).to_vec();
        assert_eq!(sp.len(), 2, "gather_blocks pool must be 2-D (rows, heads*dh)");
        assert_eq!(self.dtype(idx), DType::I32, "gather_blocks idx must be i32");
        let si = self.shape(idx).to_vec();
        assert_eq!(si.len(), 2, "gather_blocks idx must be (b, nblk)");
        assert!(block_len > 0 && sp[0] % block_len == 0, "pool rows % block_len != 0");
        assert!(heads > 0 && sp[1] % heads == 0, "pool width % heads != 0");
        let dh = sp[1] / heads;
        let shape = vec![si[0], heads, si[1] * block_len, dh];
        self.push(Op::GatherBlocks { pool, idx, block_len, heads }, shape, DType::F32)
    }

    pub fn iota(&mut self, len: usize) -> Id {
        self.push(Op::Iota { len }, vec![len], DType::F32)
    }

    // ---------------- execution ----------------

    /// Memory plan: for each node, which earlier values die after it runs.
    pub fn free_plan(&self, outputs: &[Id]) -> Vec<Vec<Id>> {
        let mut last_use = vec![usize::MAX; self.nodes.len()];
        for (id, node) in self.nodes.iter().enumerate() {
            for o in node.op.operands() {
                if last_use[o] == usize::MAX || last_use[o] < id {
                    last_use[o] = id;
                }
            }
        }
        let mut plan = vec![Vec::new(); self.nodes.len()];
        for (o, &lu) in last_use.iter().enumerate() {
            let is_input = matches!(self.nodes[o].op, Op::Input(_));
            let is_output = outputs.contains(&o);
            if lu != usize::MAX && !is_input && !is_output {
                plan[lu].push(o);
            }
        }
        plan
    }

    /// Execute over borrowed feeds with a one-shot plan and arena (tests /
    /// single-use graphs). Hot paths build an [`ExecPlan`] once and call
    /// [`Graph::eval_plan`] with a persistent [`Arena`] instead.
    pub fn eval(&self, inputs: &[Feed], outputs: &[Id]) -> Result<Vec<Value>> {
        let plan = ExecPlan::new(self, outputs);
        let mut args: Vec<Arg> = inputs.iter().map(Arg::from_feed).collect();
        self.eval_plan(&mut args, &plan, &mut Arena::new())
    }

    /// Execute the graph over manifest-ordered argument bindings, returning
    /// the values of `plan.outputs` in order. Owned arguments may be
    /// consumed in place (KV caches); borrowed arguments are never copied
    /// unless they appear as outputs. Dying intermediates are recycled
    /// through `arena`, so repeated calls with the same plan reach a
    /// steady state with no per-step allocation or planning work.
    pub fn eval_plan(
        &self,
        args: &mut [Arg],
        plan: &ExecPlan,
        arena: &mut Arena,
    ) -> Result<Vec<Value>> {
        if args.len() != self.n_inputs {
            return Err(crate::anyhow!(
                "graph expects {} inputs, got {}",
                self.n_inputs,
                args.len()
            ));
        }
        debug_assert_eq!(plan.free.len(), self.nodes.len(), "plan built for another graph");
        let mut vals: Vec<Option<Value>> = vec![None; self.nodes.len()];
        for id in 0..self.nodes.len() {
            if matches!(self.nodes[id].op, Op::Input(_) | Op::Const(_)) {
                continue; // read through `args` / the graph, never materialized
            }
            if plan.skip[id] {
                continue; // fused-group interior: computed at its root
            }
            let v = if let Some(f) = &plan.fused[id] {
                self.exec_fused(id, f, &mut vals, args, plan, arena)?
            } else {
                self.exec_node(id, &mut vals, args, plan, arena)?
            };
            debug_assert_eq!(
                v.shape(),
                self.nodes[id].shape.as_slice(),
                "node {id} ({:?}) produced wrong shape",
                self.nodes[id].op
            );
            vals[id] = Some(v);
            for &f in &plan.free[id] {
                if let Some(dead) = vals[f].take() {
                    arena.put_value(dead);
                }
            }
        }
        let mut out = Vec::with_capacity(plan.outputs.len());
        for &o in &plan.outputs {
            match &self.nodes[o].op {
                Op::Input(k) => out.push(match &mut args[*k] {
                    Arg::F32(t) => Value::F32((*t).clone()),
                    Arg::I32(t) => Value::I32((*t).clone()),
                    Arg::Q8(t) => Value::Q8((*t).clone()),
                    Arg::OwnF32(t) => Value::F32(t.take().ok_or_else(|| {
                        crate::anyhow!("output input node {o} already consumed")
                    })?),
                    Arg::OwnI32(t) => Value::I32(t.take().ok_or_else(|| {
                        crate::anyhow!("output input node {o} already consumed")
                    })?),
                    Arg::OwnQ8(t) => Value::Q8(t.take().ok_or_else(|| {
                        crate::anyhow!("output input node {o} already consumed")
                    })?),
                }),
                Op::Const(v) => out.push(v.clone()),
                _ => out.push(
                    vals[o]
                        .take()
                        .ok_or_else(|| crate::anyhow!("output node {o} was freed"))?,
                ),
            }
        }
        Ok(out)
    }

    fn f32_of<'a>(
        &'a self,
        vals: &'a [Option<Value>],
        args: &'a [Arg],
        id: Id,
    ) -> Result<&'a Tensor> {
        match &self.nodes[id].op {
            Op::Input(k) => match &args[*k] {
                Arg::F32(t) => Ok(*t),
                Arg::OwnF32(Some(t)) => Ok(t),
                Arg::OwnF32(None) => {
                    Err(crate::anyhow!("node {id}: f32 input consumed in place"))
                }
                _ => Err(crate::anyhow!("node {id}: expected f32 input")),
            },
            // constants are read straight out of the graph — never cloned
            Op::Const(v) => match v {
                Value::F32(t) => Ok(t),
                _ => Err(crate::anyhow!("node {id}: expected f32 const")),
            },
            _ => match vals[id].as_ref() {
                Some(Value::F32(t)) => Ok(t),
                Some(_) => Err(crate::anyhow!("node {id}: expected f32 value")),
                None => Err(crate::anyhow!("node {id}: value missing (freed too early?)")),
            },
        }
    }

    fn i32_of<'a>(
        &'a self,
        vals: &'a [Option<Value>],
        args: &'a [Arg],
        id: Id,
    ) -> Result<&'a IntTensor> {
        match &self.nodes[id].op {
            Op::Input(k) => match &args[*k] {
                Arg::I32(t) => Ok(*t),
                Arg::OwnI32(Some(t)) => Ok(t),
                Arg::OwnI32(None) => {
                    Err(crate::anyhow!("node {id}: i32 input consumed in place"))
                }
                _ => Err(crate::anyhow!("node {id}: expected i32 input")),
            },
            Op::Const(v) => match v {
                Value::I32(t) => Ok(t),
                _ => Err(crate::anyhow!("node {id}: expected i32 const")),
            },
            _ => match vals[id].as_ref() {
                Some(Value::I32(t)) => Ok(t),
                Some(_) => Err(crate::anyhow!("node {id}: expected i32 value")),
                None => Err(crate::anyhow!("node {id}: value missing (freed too early?)")),
            },
        }
    }

    fn q8_of<'a>(
        &'a self,
        vals: &'a [Option<Value>],
        args: &'a [Arg],
        id: Id,
    ) -> Result<&'a PackedInt8> {
        match &self.nodes[id].op {
            Op::Input(k) => match &args[*k] {
                Arg::Q8(t) => Ok(*t),
                Arg::OwnQ8(Some(t)) => Ok(t),
                Arg::OwnQ8(None) => {
                    Err(crate::anyhow!("node {id}: q8 input consumed in place"))
                }
                _ => Err(crate::anyhow!("node {id}: expected q8 input")),
            },
            Op::Const(v) => match v {
                Value::Q8(t) => Ok(t),
                _ => Err(crate::anyhow!("node {id}: expected q8 const")),
            },
            _ => match vals[id].as_ref() {
                Some(Value::Q8(t)) => Ok(t),
                Some(_) => Err(crate::anyhow!("node {id}: expected q8 value")),
                None => Err(crate::anyhow!("node {id}: value missing (freed too early?)")),
            },
        }
    }

    /// Secure the planned donor buffer for `id`: an owned input argument or
    /// a dying intermediate whose storage this node may overwrite in place.
    /// Returns `None` (fall back to an arena buffer) when the donor is a
    /// borrowed input.
    fn take_donor(
        &self,
        id: Id,
        plan: &ExecPlan,
        vals: &mut [Option<Value>],
        args: &mut [Arg],
    ) -> Option<Tensor> {
        let d = plan.donor[id]?;
        match &self.nodes[d].op {
            Op::Input(k) => match &mut args[*k] {
                Arg::OwnF32(t) => t.take(),
                _ => None,
            },
            _ => match vals[d].take() {
                Some(Value::F32(t)) => Some(t),
                Some(other) => {
                    vals[d] = Some(other);
                    None
                }
                None => None,
            },
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn unary_exec(
        &self,
        id: Id,
        x: Id,
        vals: &mut [Option<Value>],
        args: &mut [Arg],
        plan: &ExecPlan,
        arena: &mut Arena,
        f: impl Fn(f32) -> f32,
    ) -> Result<Value> {
        if let Some(mut t) = self.take_donor(id, plan, vals, args) {
            for v in t.data.iter_mut() {
                *v = f(*v);
            }
            return Ok(Value::F32(t));
        }
        let xt = self.f32_of(vals, args, x)?;
        let mut buf = arena.take(xt.data.len());
        for (o, &v) in buf.iter_mut().zip(&xt.data) {
            *o = f(v);
        }
        Ok(Value::F32(Tensor::from_vec(&self.nodes[id].shape, buf)))
    }

    #[allow(clippy::too_many_arguments)]
    fn binary_exec(
        &self,
        id: Id,
        a: Id,
        b: Id,
        vals: &mut [Option<Value>],
        args: &mut [Arg],
        plan: &ExecPlan,
        arena: &mut Arena,
        f: impl Fn(f32, f32) -> f32,
    ) -> Result<Value> {
        let out_shape = &self.nodes[id].shape;
        let path = match &plan.aux[id] {
            Aux::Ew(p) => p,
            _ => return Err(crate::anyhow!("node {id}: missing elementwise plan")),
        };
        match path {
            EwPath::Same => {
                if let Some(mut t) = self.take_donor(id, plan, vals, args) {
                    let donor = plan.donor[id].expect("donor taken ⇒ donor planned");
                    if a == b {
                        for v in t.data.iter_mut() {
                            *v = f(*v, *v);
                        }
                    } else if donor == a {
                        let bt = self.f32_of(vals, args, b)?;
                        for (x, &y) in t.data.iter_mut().zip(&bt.data) {
                            *x = f(*x, y);
                        }
                    } else {
                        let at = self.f32_of(vals, args, a)?;
                        for (y, &x) in t.data.iter_mut().zip(&at.data) {
                            *y = f(x, *y);
                        }
                    }
                    return Ok(Value::F32(t));
                }
                let at = self.f32_of(vals, args, a)?;
                let bt = self.f32_of(vals, args, b)?;
                let mut buf = arena.take(at.data.len());
                for ((o, &x), &y) in buf.iter_mut().zip(&at.data).zip(&bt.data) {
                    *o = f(x, y);
                }
                Ok(Value::F32(Tensor::from_vec(out_shape, buf)))
            }
            EwPath::ScalarR => {
                if let Some(mut t) = self.take_donor(id, plan, vals, args) {
                    let y = self.f32_of(vals, args, b)?.data[0];
                    for x in t.data.iter_mut() {
                        *x = f(*x, y);
                    }
                    return Ok(Value::F32(t));
                }
                let at = self.f32_of(vals, args, a)?;
                let y = self.f32_of(vals, args, b)?.data[0];
                let mut buf = arena.take(at.data.len());
                for (o, &x) in buf.iter_mut().zip(&at.data) {
                    *o = f(x, y);
                }
                Ok(Value::F32(Tensor::from_vec(out_shape, buf)))
            }
            EwPath::ScalarL => {
                if let Some(mut t) = self.take_donor(id, plan, vals, args) {
                    let x = self.f32_of(vals, args, a)?.data[0];
                    for y in t.data.iter_mut() {
                        *y = f(x, *y);
                    }
                    return Ok(Value::F32(t));
                }
                let x = self.f32_of(vals, args, a)?.data[0];
                let bt = self.f32_of(vals, args, b)?;
                let mut buf = arena.take(bt.data.len());
                for (o, &y) in buf.iter_mut().zip(&bt.data) {
                    *o = f(x, y);
                }
                Ok(Value::F32(Tensor::from_vec(out_shape, buf)))
            }
            EwPath::Bcast(sa, sb) => {
                let at = self.f32_of(vals, args, a)?;
                let bt = self.f32_of(vals, args, b)?;
                let r = out_shape.len();
                let mut buf = arena.take(numel(out_shape));
                let mut idx = vec![0usize; r];
                let (mut oa, mut ob) = (0usize, 0usize);
                for slot in buf.iter_mut() {
                    *slot = f(at.data[oa], bt.data[ob]);
                    for d in (0..r).rev() {
                        idx[d] += 1;
                        oa += sa[d];
                        ob += sb[d];
                        if idx[d] < out_shape[d] {
                            break;
                        }
                        idx[d] = 0;
                        oa -= sa[d] * out_shape[d];
                        ob -= sb[d] * out_shape[d];
                    }
                }
                Ok(Value::F32(Tensor::from_vec(out_shape, buf)))
            }
        }
    }

    fn exec_node(
        &self,
        id: Id,
        vals: &mut [Option<Value>],
        args: &mut [Arg],
        plan: &ExecPlan,
        arena: &mut Arena,
    ) -> Result<Value> {
        let node = &self.nodes[id];
        let out_shape = &node.shape;
        let val = match &node.op {
            Op::Input(_) | Op::Const(_) => unreachable!("inputs/consts are not materialized"),
            Op::Neg(x) => self.unary_exec(id, *x, vals, args, plan, arena, |v| -v)?,
            Op::Exp(x) => self.unary_exec(id, *x, vals, args, plan, arena, f32::exp)?,
            Op::Log(x) => self.unary_exec(id, *x, vals, args, plan, arena, f32::ln)?,
            Op::Sqrt(x) => self.unary_exec(id, *x, vals, args, plan, arena, f32::sqrt)?,
            Op::Rsqrt(x) => {
                self.unary_exec(id, *x, vals, args, plan, arena, |v| 1.0 / v.sqrt())?
            }
            Op::Tanh(x) => self.unary_exec(id, *x, vals, args, plan, arena, f32::tanh)?,
            Op::Sigmoid(x) => self.unary_exec(id, *x, vals, args, plan, arena, |v| {
                1.0 / (1.0 + (-v).exp())
            })?,
            Op::Cos(x) => self.unary_exec(id, *x, vals, args, plan, arena, f32::cos)?,
            Op::Sin(x) => self.unary_exec(id, *x, vals, args, plan, arena, f32::sin)?,
            Op::StopGrad(x) => self.unary_exec(id, *x, vals, args, plan, arena, |v| v)?,
            Op::CastF32(x) => {
                let t = self.i32_of(vals, args, *x)?;
                let mut buf = arena.take(t.data.len());
                for (o, &v) in buf.iter_mut().zip(&t.data) {
                    *o = v as f32;
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::Add(a, b) => self.binary_exec(id, *a, *b, vals, args, plan, arena, |x, y| x + y)?,
            Op::Sub(a, b) => self.binary_exec(id, *a, *b, vals, args, plan, arena, |x, y| x - y)?,
            Op::Mul(a, b) => self.binary_exec(id, *a, *b, vals, args, plan, arena, |x, y| x * y)?,
            Op::Div(a, b) => self.binary_exec(id, *a, *b, vals, args, plan, arena, |x, y| x / y)?,
            Op::Maximum(a, b) => {
                self.binary_exec(id, *a, *b, vals, args, plan, arena, f32::max)?
            }
            Op::Less(a, b) => self.binary_exec(id, *a, *b, vals, args, plan, arena, |x, y| {
                if x < y {
                    1.0
                } else {
                    0.0
                }
            })?,
            Op::Matmul { a, b, ta, tb } => {
                let at = self.f32_of(vals, args, *a)?;
                let bt = self.f32_of(vals, args, *b)?;
                let (m, n) = (out_shape[0], out_shape[1]);
                let k = if *ta { at.shape[0] } else { at.shape[1] };
                let mut buf = arena.take_filled(m * n, 0.0);
                kernels::matmul_f32(&at.data, &bt.data, m, k, n, *ta, *tb, &mut buf);
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::MatmulQ { x, w } => {
                let xt = self.f32_of(vals, args, *x)?;
                let wq = self.q8_of(vals, args, *w)?;
                let (m, n) = (out_shape[0], out_shape[1]);
                // each output element is an independent dot_q8 that
                // overwrites its slot — no pre-zero needed
                let mut buf = arena.take(m * n);
                kernels::matmul_q8(&xt.data, wq, m, &mut buf);
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::Bmm { a, b, ta, tb } => {
                let at = self.f32_of(vals, args, *a)?;
                let bt = self.f32_of(vals, args, *b)?;
                let (bs, m, n) = (out_shape[0], out_shape[1], out_shape[2]);
                let k = if *ta { at.shape[1] } else { at.shape[2] };
                let mut buf = arena.take_filled(bs * m * n, 0.0);
                kernels::bmm_f32(&at.data, &bt.data, bs, m, k, n, *ta, *tb, &mut buf);
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::Reshape(x, shape) => match self.nodes[*x].dtype {
                DType::F32 => {
                    if let Some(mut t) = self.take_donor(id, plan, vals, args) {
                        t.shape = shape.clone(); // pure metadata change, no copy
                        Value::F32(t)
                    } else {
                        let t = self.f32_of(vals, args, *x)?;
                        let mut buf = arena.take(t.data.len());
                        buf.copy_from_slice(&t.data);
                        Value::F32(Tensor::from_vec(shape, buf))
                    }
                }
                DType::I32 => {
                    let t = self.i32_of(vals, args, *x)?;
                    Value::I32(IntTensor::from_vec(shape, t.data.clone()))
                }
                DType::Q8 => {
                    return Err(crate::anyhow!(
                        "node {id}: packed q8 weights cannot be reshaped"
                    ))
                }
            },
            Op::Transpose(x, _) => {
                let t = self.f32_of(vals, args, *x)?;
                match &plan.aux[id] {
                    Aux::Walk(s) => Value::F32(walk_into(t, s, out_shape, arena)),
                    _ => return Err(crate::anyhow!("node {id}: missing transpose plan")),
                }
            }
            Op::Broadcast(x, _) => {
                if let Some(t) = self.take_donor(id, plan, vals, args) {
                    Value::F32(t) // same-shape broadcast is the identity
                } else {
                    let t = self.f32_of(vals, args, *x)?;
                    match &plan.aux[id] {
                        Aux::Walk(s) => Value::F32(walk_into(t, s, out_shape, arena)),
                        _ => return Err(crate::anyhow!("node {id}: missing broadcast plan")),
                    }
                }
            }
            Op::Concat(xs, axis) => {
                let mut parts = Vec::with_capacity(xs.len());
                for &x in xs {
                    parts.push(self.f32_of(vals, args, x)?);
                }
                let inner: usize = out_shape[*axis + 1..].iter().product();
                let outer: usize = out_shape[..*axis].iter().product();
                let mut buf = arena.take(numel(out_shape));
                let mut pos = 0usize;
                for o in 0..outer {
                    for p in &parts {
                        let len_p = p.shape[*axis] * inner;
                        buf[pos..pos + len_p].copy_from_slice(&p.data[o * len_p..(o + 1) * len_p]);
                        pos += len_p;
                    }
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::Slice { x, axis, start, len } => {
                let (x, axis, start, len) = (*x, *axis, *start, *len);
                let t = self.f32_of(vals, args, x)?;
                let n_ax = t.shape[axis];
                let inner: usize = t.shape[axis + 1..].iter().product();
                let outer: usize = t.shape[..axis].iter().product();
                let mut buf = arena.take(outer * len * inner);
                for o in 0..outer {
                    let src = (o * n_ax + start) * inner;
                    buf[o * len * inner..(o + 1) * len * inner]
                        .copy_from_slice(&t.data[src..src + len * inner]);
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::PadZero { x, axis, start, full } => {
                let (x, axis, start, full) = (*x, *axis, *start, *full);
                let t = self.f32_of(vals, args, x)?;
                let len = t.shape[axis];
                let inner: usize = t.shape[axis + 1..].iter().product();
                let outer: usize = t.shape[..axis].iter().product();
                let mut buf = arena.take_filled(outer * full * inner, 0.0);
                for o in 0..outer {
                    let dst = (o * full + start) * inner;
                    let src = o * len * inner;
                    buf[dst..dst + len * inner].copy_from_slice(&t.data[src..src + len * inner]);
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::ReduceSum(x, axis) => {
                let t = self.f32_of(vals, args, *x)?;
                Value::F32(reduce_into(t, *axis, out_shape, 0.0, |acc, v| acc + v, arena))
            }
            Op::ReduceMax(x, axis) => {
                let t = self.f32_of(vals, args, *x)?;
                Value::F32(reduce_into(t, *axis, out_shape, f32::NEG_INFINITY, f32::max, arena))
            }
            Op::Gather { table, idx } => {
                let tt = self.f32_of(vals, args, *table)?;
                let it = self.i32_of(vals, args, *idx)?;
                let (rows, d) = (tt.shape[0], tt.shape[1]);
                let mut buf = arena.take(it.data.len() * d);
                for (j, &i) in it.data.iter().enumerate() {
                    let i = i as usize;
                    if i >= rows {
                        return Err(crate::anyhow!("gather index {i} out of range (rows {rows})"));
                    }
                    buf[j * d..(j + 1) * d].copy_from_slice(&tt.data[i * d..(i + 1) * d]);
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::TakeLast { x, idx } => {
                let xt = self.f32_of(vals, args, *x)?;
                let it = self.i32_of(vals, args, *idx)?;
                let n = *xt.shape.last().unwrap();
                let mut buf = arena.take(it.data.len());
                for (j, &i) in it.data.iter().enumerate() {
                    let i = i as usize;
                    if i >= n {
                        return Err(crate::anyhow!("take_last index {i} out of range ({n})"));
                    }
                    buf[j] = xt.data[j * n + i];
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::ScatterAddRows { idx, upd, rows } => {
                let rows = *rows;
                let it = self.i32_of(vals, args, *idx)?;
                let ut = self.f32_of(vals, args, *upd)?;
                let d = *ut.shape.last().unwrap();
                let mut buf = arena.take_filled(rows * d, 0.0);
                for (j, &i) in it.data.iter().enumerate() {
                    let i = i as usize;
                    if i >= rows {
                        return Err(crate::anyhow!("scatter index {i} out of range ({rows})"));
                    }
                    let dst = &mut buf[i * d..(i + 1) * d];
                    let src = &ut.data[j * d..(j + 1) * d];
                    for (a, b) in dst.iter_mut().zip(src) {
                        *a += b;
                    }
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::ScatterLast { idx, upd, n } => {
                let n = *n;
                let it = self.i32_of(vals, args, *idx)?;
                let ut = self.f32_of(vals, args, *upd)?;
                let mut buf = arena.take_filled(ut.data.len() * n, 0.0);
                for (j, (&i, &u)) in it.data.iter().zip(&ut.data).enumerate() {
                    let i = i as usize;
                    if i >= n {
                        return Err(crate::anyhow!("scatter index {i} out of range ({n})"));
                    }
                    buf[j * n + i] = u;
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::UpdateAt { cache, kv, pos } => {
                // steal the dying cache (decode steady state: zero copies);
                // fall back to one copy when the cache is borrowed/live
                let mut ct = match self.take_donor(id, plan, vals, args) {
                    Some(t) => t,
                    None => {
                        let c = self.f32_of(vals, args, *cache)?;
                        let mut buf = arena.take(c.data.len());
                        buf.copy_from_slice(&c.data);
                        Tensor::from_vec(&c.shape, buf)
                    }
                };
                let kt = self.f32_of(vals, args, *kv)?;
                let pt = self.i32_of(vals, args, *pos)?;
                let (b, h, s, d) = (ct.shape[0], ct.shape[1], ct.shape[2], ct.shape[3]);
                for bb in 0..b {
                    let p = pt.data[bb] as usize;
                    if p >= s {
                        return Err(crate::anyhow!("update_at position {p} out of range ({s})"));
                    }
                    for hh in 0..h {
                        let dst = (bb * h + hh) * s * d + p * d;
                        let src = (bb * h + hh) * d;
                        ct.data[dst..dst + d].copy_from_slice(&kt.data[src..src + d]);
                    }
                }
                Value::F32(ct)
            }
            Op::UpdateRows { table, upd, pos } => {
                // steal the dying pool (paged decode steady state: zero
                // copies); fall back to one copy when the table is live
                let mut tt = match self.take_donor(id, plan, vals, args) {
                    Some(t) => t,
                    None => {
                        let t = self.f32_of(vals, args, *table)?;
                        let mut buf = arena.take(t.data.len());
                        buf.copy_from_slice(&t.data);
                        Tensor::from_vec(&t.shape, buf)
                    }
                };
                let ut = self.f32_of(vals, args, *upd)?;
                let pt = self.i32_of(vals, args, *pos)?;
                let (rows, d) = (tt.shape[0], tt.shape[1]);
                for (j, &p) in pt.data.iter().enumerate() {
                    let p = p as usize;
                    if p >= rows {
                        return Err(crate::anyhow!(
                            "update_rows position {p} out of range ({rows})"
                        ));
                    }
                    tt.data[p * d..(p + 1) * d].copy_from_slice(&ut.data[j * d..(j + 1) * d]);
                }
                Value::F32(tt)
            }
            Op::GatherBlocks { pool, idx, block_len, heads } => {
                let (bl, hs) = (*block_len, *heads);
                let pt = self.f32_of(vals, args, *pool)?;
                let it = self.i32_of(vals, args, *idx)?;
                let width = pt.shape[1];
                let dh = width / hs;
                let nb = pt.shape[0] / bl;
                let (b, nblk) = (it.shape[0], it.shape[1]);
                let s = nblk * bl;
                let mut buf = arena.take(b * hs * s * dh);
                for bb in 0..b {
                    for (j, &blk) in it.data[bb * nblk..(bb + 1) * nblk].iter().enumerate() {
                        let blk = blk as usize;
                        if blk >= nb {
                            return Err(crate::anyhow!(
                                "gather_blocks block id {blk} out of range ({nb})"
                            ));
                        }
                        for o in 0..bl {
                            let src = (blk * bl + o) * width;
                            let dst_t = j * bl + o;
                            for h in 0..hs {
                                let dst = ((bb * hs + h) * s + dst_t) * dh;
                                buf[dst..dst + dh]
                                    .copy_from_slice(&pt.data[src + h * dh..src + (h + 1) * dh]);
                            }
                        }
                    }
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            Op::Iota { len } => {
                let mut buf = arena.take(*len);
                for (i, slot) in buf.iter_mut().enumerate() {
                    *slot = i as f32;
                }
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
        };
        Ok(val)
    }

    /// Obtain a writable copy of f32 node `x`'s value at `id`: the planned
    /// in-place donor when available, otherwise an arena buffer holding a
    /// copy, shaped as `id`'s output.
    fn writable_copy(
        &self,
        id: Id,
        x: Id,
        vals: &mut [Option<Value>],
        args: &mut [Arg],
        plan: &ExecPlan,
        arena: &mut Arena,
    ) -> Result<Tensor> {
        if let Some(t) = self.take_donor(id, plan, vals, args) {
            return Ok(t);
        }
        let xt = self.f32_of(vals, args, x)?;
        let mut buf = arena.take(xt.data.len());
        buf.copy_from_slice(&xt.data);
        Ok(Tensor::from_vec(&self.nodes[id].shape, buf))
    }

    /// Execute one fused group at its root node. Every kernel below runs
    /// the *same primitive f32 operations in the same order* as the unfused
    /// op chain it replaces, so results are bitwise identical — fusion only
    /// removes intermediate materialization (see [`crate::runtime::fusion`]).
    fn exec_fused(
        &self,
        id: Id,
        f: &FusedOp,
        vals: &mut [Option<Value>],
        args: &mut [Arg],
        plan: &ExecPlan,
        arena: &mut Arena,
    ) -> Result<Value> {
        let out_shape = &self.nodes[id].shape;
        let val = match f {
            FusedOp::Softmax { x, rows, n } => {
                let mut t = self.writable_copy(id, *x, vals, args, plan, arena)?;
                softmax_rows(&mut t.data, *rows, *n);
                Value::F32(t)
            }
            FusedOp::RmsNorm { x, gain, rows, d, inv_d, eps } => {
                let mut t = self.writable_copy(id, *x, vals, args, plan, arena)?;
                let gt = self.f32_of(vals, args, *gain)?;
                rmsnorm_rows(&mut t.data, &gt.data, *rows, *d, *inv_d, *eps);
                Value::F32(t)
            }
            FusedOp::RmsNormMatmul { x, gain, w, tb, rows, d, n, inv_d, eps } => {
                let xt = self.f32_of(vals, args, *x)?;
                let mut scratch = arena.take(rows * d);
                scratch.copy_from_slice(&xt.data);
                let gt = self.f32_of(vals, args, *gain)?;
                rmsnorm_rows(&mut scratch, &gt.data, *rows, *d, *inv_d, *eps);
                let wt = self.f32_of(vals, args, *w)?;
                let mut buf = arena.take_filled(rows * n, 0.0);
                kernels::matmul_f32(&scratch, &wt.data, *rows, *d, *n, false, *tb, &mut buf);
                arena.put(scratch);
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
            FusedOp::Rope { x, ang, b, t, pb, h, dh } => {
                let mut xt = self.writable_copy(id, *x, vals, args, plan, arena)?;
                let at = self.f32_of(vals, args, *ang)?;
                rope_inplace(&mut xt.data, &at.data, *b, *t, *pb, *h, *dh, arena);
                Value::F32(xt)
            }
            FusedOp::RopeScore { x, ang, k, b, pb, h, dh, n } => {
                let bs = b * h;
                let xt = self.f32_of(vals, args, *x)?;
                let mut q = arena.take(bs * dh);
                q.copy_from_slice(&xt.data);
                let at = self.f32_of(vals, args, *ang)?;
                rope_inplace(&mut q, &at.data, *b, 1, *pb, *h, *dh, arena);
                let kt = self.f32_of(vals, args, *k)?;
                let mut buf = arena.take_filled(bs * n, 0.0);
                kernels::bmm_f32(&q, &kt.data, bs, 1, *dh, *n, false, true, &mut buf);
                arena.put(q);
                Value::F32(Tensor::from_vec(out_shape, buf))
            }
        };
        Ok(val)
    }
}

/// Shifted softmax over `rows` contiguous rows of length `n`, in place.
/// Primitive order matches the unfused chain exactly: max fold (init
/// `NEG_INFINITY`, ascending), `(x - m).exp()`, ascending sum from 0.0,
/// divide — bitwise identical to ReduceMax/Sub/Exp/ReduceSum/Div.
fn softmax_rows(data: &mut [f32], rows: usize, n: usize) {
    for r in 0..rows {
        let row = &mut data[r * n..(r + 1) * n];
        let mut m = f32::NEG_INFINITY;
        for &v in row.iter() {
            m = f32::max(m, v);
        }
        for v in row.iter_mut() {
            *v = (*v - m).exp();
        }
        let mut s = 0.0f32;
        for &v in row.iter() {
            s += v;
        }
        for v in row.iter_mut() {
            *v /= s;
        }
    }
}

/// RMSNorm `rows` contiguous rows of length `d` in place against `gain`.
/// Primitive order matches the unfused chain exactly: ascending sum of
/// `v*v`, `1.0 / (ss*inv_d + eps).sqrt()`, then `(v * inv) * g` — bitwise
/// identical to Mul/ReduceSum/Mul/Add/Rsqrt/Mul/Mul.
fn rmsnorm_rows(data: &mut [f32], gain: &[f32], rows: usize, d: usize, inv_d: f32, eps: f32) {
    for r in 0..rows {
        let row = &mut data[r * d..(r + 1) * d];
        let mut ss = 0.0f32;
        for &v in row.iter() {
            ss += v * v;
        }
        let inv = 1.0 / (ss * inv_d + eps).sqrt();
        for (v, &g) in row.iter_mut().zip(gain) {
            *v = (*v * inv) * g;
        }
    }
}

/// Rotary embedding of `x` (b, t, h, dh) against angles (pb, t, dh/2) in
/// place, `pb ∈ {1, b}`. Per (batch, position) the cos/sin vectors are
/// computed once into a scratch pair, then every head applies
/// `lo = (x1*c) - (x2*s); hi = (x1*s) + (x2*c)` — the exact unfused
/// Cos/Sin/Mul/Sub/Add order, bitwise identical.
#[allow(clippy::too_many_arguments)]
fn rope_inplace(
    x: &mut [f32],
    ang: &[f32],
    b: usize,
    t: usize,
    pb: usize,
    h: usize,
    dh: usize,
    arena: &mut Arena,
) {
    let half = dh / 2;
    let mut cs = arena.take(2 * half);
    {
        let (cbuf, sbuf) = cs.split_at_mut(half);
        for bb in 0..b {
            let ab = if pb == 1 { 0 } else { bb };
            for tt in 0..t {
                let abase = (ab * t + tt) * half;
                for j in 0..half {
                    cbuf[j] = ang[abase + j].cos();
                    sbuf[j] = ang[abase + j].sin();
                }
                for hh in 0..h {
                    let base = ((bb * t + tt) * h + hh) * dh;
                    for j in 0..half {
                        let x1 = x[base + j];
                        let x2 = x[base + half + j];
                        x[base + j] = (x1 * cbuf[j]) - (x2 * sbuf[j]);
                        x[base + half + j] = (x1 * sbuf[j]) + (x2 * cbuf[j]);
                    }
                }
            }
        }
    }
    arena.put(cs);
}

// ---------------------------------------------------------------------------
// Execution plan, argument bindings, buffer arena
// ---------------------------------------------------------------------------

/// One bound input for [`Graph::eval_plan`]: borrowed for tensors the
/// caller retains (weights), owned for per-step values the evaluator may
/// consume in place (KV caches, tokens).
pub enum Arg<'a> {
    F32(&'a Tensor),
    I32(&'a IntTensor),
    Q8(&'a PackedInt8),
    OwnF32(Option<Tensor>),
    OwnI32(Option<IntTensor>),
    OwnQ8(Option<PackedInt8>),
}

impl<'a> Arg<'a> {
    pub fn from_feed(f: &Feed<'a>) -> Arg<'a> {
        match f {
            Feed::F32(t) => Arg::F32(t),
            Feed::I32(t) => Arg::I32(t),
            Feed::Q8(t) => Arg::Q8(t),
        }
    }

    pub fn from_value(v: Value) -> Arg<'a> {
        match v {
            Value::F32(t) => Arg::OwnF32(Some(t)),
            Value::I32(t) => Arg::OwnI32(Some(t)),
            Value::Q8(t) => Arg::OwnQ8(Some(t)),
        }
    }
}

/// Elementwise dispatch decided once at plan time from the static shapes.
enum EwPath {
    /// Both operands already have the output shape.
    Same,
    /// Right operand is a scalar, left has the output shape.
    ScalarR,
    /// Left operand is a scalar, right has the output shape.
    ScalarL,
    /// General broadcast: precomputed per-dim strides for both operands.
    Bcast(Vec<usize>, Vec<usize>),
}

/// Per-node precomputed execution metadata.
enum Aux {
    None,
    Ew(EwPath),
    /// Per-output-dim input strides (transpose gather / broadcast walk).
    Walk(Vec<usize>),
}

/// Everything the evaluator precomputes once per (graph, outputs): last-use
/// free lists, in-place donors, and stride/broadcast walks. Built once at
/// artifact load and reused for every execution, so the per-node hot path
/// does no shape/stride math and no planning.
pub struct ExecPlan {
    pub outputs: Vec<Id>,
    /// For each node, which earlier values die after it runs.
    free: Vec<Vec<Id>>,
    /// For each node, the operand whose buffer it may overwrite in place
    /// (its last use, not an output, not a constant, compatible layout).
    donor: Vec<Option<Id>>,
    aux: Vec<Aux>,
    /// Fused group rooted at each node ([`plan_fusion`]); all `None` when
    /// fusion is off.
    fused: Vec<Option<FusedOp>>,
    /// Fused-group interiors: never executed, never materialized.
    skip: Vec<bool>,
}

/// Process-wide fusion default, latched once from `ARA_FUSE` (on unless
/// set to `0`/`off`/`false`).
fn fuse_default() -> bool {
    static FUSE: OnceLock<bool> = OnceLock::new();
    *FUSE.get_or_init(|| match std::env::var("ARA_FUSE") {
        Ok(v) => !matches!(v.trim().to_ascii_lowercase().as_str(), "0" | "off" | "false"),
        Err(_) => true,
    })
}

impl ExecPlan {
    pub fn new(g: &Graph, outputs: &[Id]) -> ExecPlan {
        ExecPlan::new_with(g, outputs, fuse_default())
    }

    /// Number of fused groups in this plan (observability / tests).
    pub fn fused_count(&self) -> usize {
        self.fused.iter().flatten().count()
    }

    /// Build a plan with fusion explicitly on or off. Fused and unfused
    /// plans produce bitwise-identical outputs (see [`plan_fusion`]).
    pub fn new_with(g: &Graph, outputs: &[Id], fuse: bool) -> ExecPlan {
        let n = g.nodes.len();
        let fplan =
            if fuse { plan_fusion(g, outputs) } else { FusionPlan::disabled(n) };
        // Effective last use: an operand read at a fused-group interior
        // happens when the group's root executes, so deaths are attributed
        // to the root's position. Roots sit after their interiors but sites
        // are no longer monotonic in id, hence the guarded max.
        let mut last_use = vec![usize::MAX; n];
        for (id, node) in g.nodes.iter().enumerate() {
            let site = fplan.root_of[id];
            for o in node.op.operands() {
                if last_use[o] == usize::MAX || last_use[o] < site {
                    last_use[o] = site;
                }
            }
        }
        let mut free = vec![Vec::new(); n];
        for (o, &lu) in last_use.iter().enumerate() {
            let keep = matches!(g.nodes[o].op, Op::Input(_) | Op::Const(_))
                || outputs.contains(&o)
                || fplan.skip[o];
            if lu != usize::MAX && !keep {
                free[lu].push(o);
            }
        }
        let mut donor: Vec<Option<Id>> = vec![None; n];
        let mut aux: Vec<Aux> = Vec::with_capacity(n);
        let donatable = |o: Id, id: Id, shape: &[usize]| -> bool {
            last_use[o] == id
                && !fplan.skip[o]
                && !outputs.contains(&o)
                && !matches!(g.nodes[o].op, Op::Const(_))
                && g.nodes[o].shape == shape
        };
        for (id, node) in g.nodes.iter().enumerate() {
            let out_shape = node.shape.as_slice();
            if fplan.skip[id] {
                aux.push(Aux::None); // never executed
                continue;
            }
            if let Some(f) = &fplan.fused[id] {
                // In-place fused groups may steal their input's buffer
                // (root output shape equals the input shape for all three).
                let inp = match f {
                    FusedOp::Softmax { x, .. }
                    | FusedOp::RmsNorm { x, .. }
                    | FusedOp::Rope { x, .. } => Some(*x),
                    FusedOp::RmsNormMatmul { .. } | FusedOp::RopeScore { .. } => None,
                };
                if let Some(x) = inp {
                    if donatable(x, id, out_shape) {
                        donor[id] = Some(x);
                    }
                }
                aux.push(Aux::None);
                continue;
            }
            let a = match &node.op {
                Op::Neg(x)
                | Op::Exp(x)
                | Op::Log(x)
                | Op::Sqrt(x)
                | Op::Rsqrt(x)
                | Op::Tanh(x)
                | Op::Sigmoid(x)
                | Op::Cos(x)
                | Op::Sin(x)
                | Op::StopGrad(x) => {
                    if donatable(*x, id, out_shape) {
                        donor[id] = Some(*x);
                    }
                    Aux::None
                }
                Op::Reshape(x, _) if node.dtype == DType::F32 => {
                    // shapes differ but the flat buffer is reusable as-is
                    if last_use[*x] == id
                        && !outputs.contains(x)
                        && !matches!(g.nodes[*x].op, Op::Const(_))
                        && numel(&g.nodes[*x].shape) == numel(out_shape)
                    {
                        donor[id] = Some(*x);
                    }
                    Aux::None
                }
                Op::Add(a, b)
                | Op::Sub(a, b)
                | Op::Mul(a, b)
                | Op::Div(a, b)
                | Op::Maximum(a, b)
                | Op::Less(a, b) => {
                    let sa = g.nodes[*a].shape.as_slice();
                    let sb = g.nodes[*b].shape.as_slice();
                    let path = if sa == out_shape && sb == out_shape {
                        if donatable(*a, id, out_shape) {
                            donor[id] = Some(*a);
                        } else if *b != *a && donatable(*b, id, out_shape) {
                            donor[id] = Some(*b);
                        }
                        EwPath::Same
                    } else if numel(sb) == 1 && sa == out_shape {
                        if donatable(*a, id, out_shape) {
                            donor[id] = Some(*a);
                        }
                        EwPath::ScalarR
                    } else if numel(sa) == 1 && sb == out_shape {
                        if donatable(*b, id, out_shape) {
                            donor[id] = Some(*b);
                        }
                        EwPath::ScalarL
                    } else {
                        EwPath::Bcast(bcast_strides(sa, out_shape), bcast_strides(sb, out_shape))
                    };
                    Aux::Ew(path)
                }
                Op::Transpose(x, perm) => {
                    let xs = &g.nodes[*x].shape;
                    let r = out_shape.len();
                    let mut in_strides = vec![1usize; r];
                    for d in (0..r.saturating_sub(1)).rev() {
                        in_strides[d] = in_strides[d + 1] * xs[d + 1];
                    }
                    Aux::Walk(perm.iter().map(|&p| in_strides[p]).collect())
                }
                Op::Broadcast(x, shape) => {
                    if donatable(*x, id, shape) {
                        donor[id] = Some(*x);
                    }
                    Aux::Walk(bcast_strides(&g.nodes[*x].shape, shape))
                }
                Op::UpdateAt { cache, .. } => {
                    if donatable(*cache, id, out_shape) {
                        donor[id] = Some(*cache);
                    }
                    Aux::None
                }
                Op::UpdateRows { table, .. } => {
                    if donatable(*table, id, out_shape) {
                        donor[id] = Some(*table);
                    }
                    Aux::None
                }
                _ => Aux::None,
            };
            aux.push(a);
        }
        ExecPlan {
            outputs: outputs.to_vec(),
            free,
            donor,
            aux,
            fused: fplan.fused,
            skip: fplan.skip,
        }
    }
}

/// Size-keyed recycling pool for f32 buffers: dying graph values are
/// returned here and handed back to later nodes of the same size, so
/// steady-state execution (the decode loop, repeated train steps) does no
/// per-step heap churn.
#[derive(Default)]
pub struct Arena {
    pool: HashMap<usize, Vec<Vec<f32>>>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    fn put(&mut self, data: Vec<f32>) {
        if data.is_empty() {
            return;
        }
        let bucket = self.pool.entry(data.len()).or_default();
        if bucket.len() < 16 {
            bucket.push(data);
        }
    }

    fn put_value(&mut self, v: Value) {
        if let Value::F32(t) = v {
            self.put(t.data);
        }
    }

    /// A buffer of exactly `len` elements with **unspecified contents**;
    /// the caller must overwrite every element.
    fn take(&mut self, len: usize) -> Vec<f32> {
        match self.pool.get_mut(&len).and_then(|b| b.pop()) {
            Some(buf) => buf,
            None => vec![0.0; len],
        }
    }

    /// A buffer of `len` elements, every element set to `v`.
    fn take_filled(&mut self, len: usize, v: f32) -> Vec<f32> {
        match self.pool.get_mut(&len).and_then(|b| b.pop()) {
            Some(mut buf) => {
                buf.fill(v);
                buf
            }
            None => vec![v; len],
        }
    }
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

/// Gather `t.data` through per-output-dim `strides` into a fresh buffer
/// (transpose and broadcast share this walk; strides come from the plan).
fn walk_into(t: &Tensor, strides: &[usize], out_shape: &[usize], arena: &mut Arena) -> Tensor {
    let r = out_shape.len();
    let mut buf = arena.take(numel(out_shape));
    let mut idx = vec![0usize; r];
    let mut off = 0usize;
    for slot in buf.iter_mut() {
        *slot = t.data[off];
        for d in (0..r).rev() {
            idx[d] += 1;
            off += strides[d];
            if idx[d] < out_shape[d] {
                break;
            }
            idx[d] = 0;
            off -= strides[d] * out_shape[d];
        }
    }
    Tensor::from_vec(out_shape, buf)
}

fn reduce_into(
    t: &Tensor,
    axis: usize,
    out_shape: &[usize],
    init: f32,
    f: impl Fn(f32, f32) -> f32,
    arena: &mut Arena,
) -> Tensor {
    let n = t.shape[axis];
    let outer: usize = t.shape[..axis].iter().product();
    let inner: usize = t.shape[axis + 1..].iter().product();
    let mut buf = arena.take_filled(outer * inner, init);
    for o in 0..outer {
        for kk in 0..n {
            let base = (o * n + kk) * inner;
            let orow = &mut buf[o * inner..(o + 1) * inner];
            for (x, &v) in orow.iter_mut().zip(&t.data[base..base + inner]) {
                *x = f(*x, v);
            }
        }
    }
    Tensor::from_vec(out_shape, buf)
}

/// Right-aligned broadcast strides of `shape` against `out` (0 where the
/// input dimension is 1 or absent).
fn bcast_strides(shape: &[usize], out: &[usize]) -> Vec<usize> {
    let r = out.len();
    let pad = r - shape.len();
    // row-major strides of the (padded) input shape
    let mut strides = vec![0usize; r];
    let mut acc = 1usize;
    for d in (0..shape.len()).rev() {
        strides[pad + d] = if shape[d] == 1 { 0 } else { acc };
        acc *= shape[d];
    }
    // padded leading dims broadcast with stride 0 (already zeroed)
    for (d, s) in strides.iter_mut().enumerate() {
        if out[d] == 1 {
            *s = 0; // degenerate output dim; stride irrelevant
        }
    }
    strides
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::from_vec(shape, data)
    }

    fn run1(g: &Graph, out: Id, feeds: &[Feed]) -> Tensor {
        match g.eval(feeds, &[out]).unwrap().remove(0) {
            Value::F32(t) => t,
            other => panic!("expected f32, got {other:?}"),
        }
    }

    #[test]
    fn broadcast_shapes_numpy_rules() {
        assert_eq!(broadcast_shapes(&[4, 1], &[3]), Some(vec![4, 3]));
        assert_eq!(broadcast_shapes(&[2, 3], &[2, 3]), Some(vec![2, 3]));
        assert_eq!(broadcast_shapes(&[], &[5]), Some(vec![5]));
        assert_eq!(broadcast_shapes(&[2, 1, 4], &[3, 1]), Some(vec![2, 3, 4]));
        assert_eq!(broadcast_shapes(&[2, 3], &[4]), None);
    }

    #[test]
    fn elementwise_broadcast_matches_manual() {
        let mut g = Graph::default();
        let a = g.input(&[2, 3], DType::F32);
        let b = g.input(&[3], DType::F32);
        let c = g.mul(a, b);
        let at = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let bt = t(&[3], vec![10., 100., 1000.]);
        let got = run1(&g, c, &[Feed::F32(&at), Feed::F32(&bt)]);
        assert_eq!(got.data, vec![10., 200., 3000., 40., 500., 6000.]);
    }

    #[test]
    fn matmul_all_transpose_combos() {
        // A (2,3), B (3,2) — compare every flag combo against the plain one
        let a = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = t(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let expect = a.matmul(&b); // (2,2)

        let mut g = Graph::default();
        let ia = g.input(&[2, 3], DType::F32);
        let ib = g.input(&[3, 2], DType::F32);
        let c0 = g.matmul(ia, ib, false, false);
        assert_eq!(run1(&g, c0, &[Feed::F32(&a), Feed::F32(&b)]).data, expect.data);

        let at = a.transpose2(); // (3,2)
        let mut g = Graph::default();
        let ia = g.input(&[3, 2], DType::F32);
        let ib = g.input(&[3, 2], DType::F32);
        let c1 = g.matmul(ia, ib, true, false);
        assert_eq!(run1(&g, c1, &[Feed::F32(&at), Feed::F32(&b)]).data, expect.data);

        let bt = b.transpose2(); // (2,3)
        let mut g = Graph::default();
        let ia = g.input(&[2, 3], DType::F32);
        let ib = g.input(&[2, 3], DType::F32);
        let c2 = g.matmul(ia, ib, false, true);
        assert_eq!(run1(&g, c2, &[Feed::F32(&a), Feed::F32(&bt)]).data, expect.data);

        let mut g = Graph::default();
        let ia = g.input(&[3, 2], DType::F32);
        let ib = g.input(&[2, 3], DType::F32);
        let c3 = g.matmul(ia, ib, true, true);
        assert_eq!(run1(&g, c3, &[Feed::F32(&at), Feed::F32(&bt)]).data, expect.data);
    }

    #[test]
    fn matmul_q_matches_dequant_matmul_bitwise() {
        // m < 8 keeps the f32 reference on the same dot micro-kernel
        // schedule the q8 kernel mirrors, so through the full interpreter
        // path (feeds → exec → arena) equality is BITWISE, not approximate.
        // k = 70 with group 32 leaves a ragged 6-wide last scale group.
        let (m, k, n, group) = (3usize, 70usize, 9usize, 32usize);
        let mut s = 0x9e3779b97f4a7c15u64;
        let mut fill = |len: usize| -> Vec<f32> {
            (0..len)
                .map(|_| {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                    ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5
                })
                .collect()
        };
        let x = t(&[m, k], fill(m * k));
        let w = t(&[n, k], fill(n * k));
        let q = PackedInt8::quantize(&w, group);
        let dq = q.dequant();

        let mut g = Graph::default();
        let ix = g.input(&[m, k], DType::F32);
        let iw = g.input(&[n, k], DType::Q8);
        let y = g.matmul_q(ix, iw);
        assert_eq!(g.shape(y), &[m, n][..]);
        let got = run1(&g, y, &[Feed::F32(&x), Feed::Q8(&q)]);

        let mut g2 = Graph::default();
        let ix2 = g2.input(&[m, k], DType::F32);
        let iw2 = g2.input(&[n, k], DType::F32);
        let y2 = g2.matmul(ix2, iw2, false, true);
        let want = run1(&g2, y2, &[Feed::F32(&x), Feed::F32(&dq)]);

        assert_eq!(got.shape, want.shape);
        for (i, (a, b)) in got.data.iter().zip(&want.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "elem {i}: {a:e} vs {b:e}");
        }
    }

    #[test]
    fn q8_weights_cannot_be_reshaped() {
        let mut g = Graph::default();
        let iw = g.input(&[4, 6], DType::Q8);
        let r = g.reshape(iw, &[6, 4]);
        let w = t(&[4, 6], vec![0.25; 24]);
        let q = PackedInt8::quantize(&w, 3);
        let err = g.eval(&[Feed::Q8(&q)], &[r]).unwrap_err().to_string();
        assert!(err.contains("reshape"), "{err}");
    }

    #[test]
    fn bmm_matches_per_slice_matmul() {
        let a = t(&[2, 2, 3], (0..12).map(|x| x as f32).collect());
        let b = t(&[2, 3, 2], (0..12).map(|x| (x as f32) * 0.5).collect());
        let mut g = Graph::default();
        let ia = g.input(&[2, 2, 3], DType::F32);
        let ib = g.input(&[2, 3, 2], DType::F32);
        let c = g.bmm(ia, ib, false, false);
        let got = run1(&g, c, &[Feed::F32(&a), Feed::F32(&b)]);
        for s in 0..2 {
            let a2 = t(&[2, 3], a.data[s * 6..(s + 1) * 6].to_vec());
            let b2 = t(&[3, 2], b.data[s * 6..(s + 1) * 6].to_vec());
            let e = a2.matmul(&b2);
            assert_eq!(&got.data[s * 4..(s + 1) * 4], e.data.as_slice(), "slice {s}");
        }
    }

    #[test]
    fn reduce_and_keepdims() {
        let x = t(&[2, 3], vec![1., 5., 2., -1., 0., 4.]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let s = g.reduce_sum(ix, 1);
        let m = g.reduce_max(ix, 0);
        let out = g.eval(&[Feed::F32(&x)], &[s, m]).unwrap();
        assert_eq!(out[0].to_f32_tensor().data, vec![8., 3.]);
        assert_eq!(out[1].to_f32_tensor().data, vec![1., 5., 4.]);
    }

    #[test]
    fn transpose_reshape_slice_pad_roundtrip() {
        let x = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let tr = g.transpose(ix, &[1, 0]);
        let got = run1(&g, tr, &[Feed::F32(&x)]);
        assert_eq!(got.data, vec![1., 4., 2., 5., 3., 6.]);

        let mut g = Graph::default();
        let ix = g.input(&[2, 4], DType::F32);
        let sl = g.slice(ix, 1, 1, 2);
        let pd = g.pad_zero(sl, 1, 1, 4);
        let x = t(&[2, 4], vec![1., 2., 3., 4., 5., 6., 7., 8.]);
        let got = run1(&g, pd, &[Feed::F32(&x)]);
        assert_eq!(got.data, vec![0., 2., 3., 0., 0., 6., 7., 0.]);
    }

    #[test]
    fn gather_take_scatter() {
        let table = t(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let idx = IntTensor::from_vec(&[2, 2], vec![2, 0, 1, 2]);
        let mut g = Graph::default();
        let it = g.input(&[3, 2], DType::F32);
        let ii = g.input(&[2, 2], DType::I32);
        let gat = g.gather(it, ii);
        let got = run1(&g, gat, &[Feed::F32(&table), Feed::I32(&idx)]);
        assert_eq!(got.shape, vec![2, 2, 2]);
        assert_eq!(got.data, vec![5., 6., 1., 2., 3., 4., 5., 6.]);

        // scatter_add_rows is the adjoint: sum of rows per index
        let upd = t(&[2, 2, 2], vec![1.; 8]);
        let mut g = Graph::default();
        let ii = g.input(&[2, 2], DType::I32);
        let iu = g.input(&[2, 2, 2], DType::F32);
        let sc = g.scatter_add_rows(ii, iu, 3);
        let got = run1(&g, sc, &[Feed::I32(&idx), Feed::F32(&upd)]);
        // index 2 hit twice, 0 and 1 once each
        assert_eq!(got.data, vec![1., 1., 1., 1., 2., 2.]);

        // take_last / scatter_last
        let x = t(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let ti = IntTensor::from_vec(&[2], vec![2, 0]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let ii = g.input(&[2], DType::I32);
        let tk = g.take_last(ix, ii);
        let got = run1(&g, tk, &[Feed::F32(&x), Feed::I32(&ti)]);
        assert_eq!(got.data, vec![3., 4.]);
    }

    #[test]
    fn update_at_writes_per_batch_position() {
        // cache (2,1,3,2), kv (2,1,2), pos [2,0]
        let cache = t(&[2, 1, 3, 2], vec![0.0; 12]);
        let kv = t(&[2, 1, 2], vec![1., 2., 3., 4.]);
        let pos = IntTensor::from_vec(&[2], vec![2, 0]);
        let mut g = Graph::default();
        let ic = g.input(&[2, 1, 3, 2], DType::F32);
        let ik = g.input(&[2, 1, 2], DType::F32);
        let ip = g.input(&[2], DType::I32);
        let up = g.update_at(ic, ik, ip);
        let got = run1(&g, up, &[Feed::F32(&cache), Feed::F32(&kv), Feed::I32(&pos)]);
        assert_eq!(got.data, vec![0., 0., 0., 0., 1., 2., 3., 4., 0., 0., 0., 0.]);
    }

    #[test]
    fn softmax_composed_from_ops_matches_manual() {
        // softmax over the last axis, composed exactly like the attention graph
        let x = t(&[2, 3], vec![1., 2., 3., 0., 0., 0.]);
        let mut g = Graph::default();
        let ix = g.input(&[2, 3], DType::F32);
        let m = g.reduce_max_keep(ix, 1);
        let ms = g.stop_grad(m);
        let sh = g.sub(ix, ms);
        let e = g.exp(sh);
        let s = g.reduce_sum_keep(e, 1);
        let p = g.div(e, s);
        let got = run1(&g, p, &[Feed::F32(&x)]);
        let z: f32 = (1.0f32).exp() + (2.0f32).exp() + (3.0f32).exp();
        let e1 = (1.0f32).exp() / z;
        assert!((got.data[0] - e1).abs() < 1e-6);
        let row1: f32 = got.data[3..].iter().sum();
        assert!((row1 - 1.0).abs() < 1e-6);
        for v in &got.data[3..] {
            assert!((v - 1.0 / 3.0).abs() < 1e-6);
        }
    }

    #[test]
    fn tanh_rsqrt_maximum_elementwise() {
        let x = t(&[3], vec![0.25, 1.0, 4.0]);
        let y = t(&[3], vec![1.0, -1.0, 5.0]);
        let mut g = Graph::default();
        let ix = g.input(&[3], DType::F32);
        let iy = g.input(&[3], DType::F32);
        let r = g.rsqrt(ix);
        let th = g.tanh(iy);
        let mx = g.maximum(ix, iy);
        let out = g.eval(&[Feed::F32(&x), Feed::F32(&y)], &[r, th, mx]).unwrap();
        let rt = out[0].to_f32_tensor();
        assert!((rt.data[0] - 2.0).abs() < 1e-6);
        assert!((rt.data[1] - 1.0).abs() < 1e-6);
        assert!((rt.data[2] - 0.5).abs() < 1e-6);
        let tt = out[1].to_f32_tensor();
        assert!((tt.data[0] - (1.0f32).tanh()).abs() < 1e-6);
        assert_eq!(out[2].to_f32_tensor().data, vec![1.0, 1.0, 5.0]);
    }

    #[test]
    fn free_plan_never_frees_outputs_or_inputs() {
        let mut g = Graph::default();
        let a = g.input(&[2], DType::F32);
        let b = g.add(a, a);
        let c = g.mul(b, b);
        let plan = g.free_plan(&[c, b]);
        // b is an output — must never appear in any free list
        for l in &plan {
            assert!(!l.contains(&b));
            assert!(!l.contains(&a));
        }
        let x = t(&[2], vec![1., 2.]);
        let out = g.eval(&[Feed::F32(&x)], &[c, b]).unwrap();
        assert_eq!(out[0].to_f32_tensor().data, vec![4., 16.]);
        assert_eq!(out[1].to_f32_tensor().data, vec![2., 4.]);
    }

    #[test]
    fn update_at_steals_owned_cache_in_place() {
        // decode-shaped graph: cache input → update_at → output. With an
        // owned cache argument the update must reuse the same allocation.
        let mut g = Graph::default();
        let c = g.input(&[1, 1, 3, 2], DType::F32);
        let kv = g.input(&[1, 1, 2], DType::F32);
        let p = g.input(&[1], DType::I32);
        let up = g.update_at(c, kv, p);
        let plan = ExecPlan::new(&g, &[up]);
        let cache = Tensor::zeros(&[1, 1, 3, 2]);
        let ptr = cache.data.as_ptr();
        let kvt = t(&[1, 1, 2], vec![1., 2.]);
        let pos = IntTensor::from_vec(&[1], vec![1]);
        let mut args = vec![
            Arg::from_value(Value::F32(cache)),
            Arg::F32(&kvt),
            Arg::I32(&pos),
        ];
        let out = g.eval_plan(&mut args, &plan, &mut Arena::new()).unwrap();
        let Value::F32(got) = &out[0] else { panic!("expected f32") };
        assert_eq!(got.data, vec![0., 0., 1., 2., 0., 0.]);
        assert_eq!(got.data.as_ptr(), ptr, "cache must be updated in place");
    }

    #[test]
    fn update_at_with_borrowed_cache_copies_and_preserves_input() {
        let mut g = Graph::default();
        let c = g.input(&[1, 1, 3, 2], DType::F32);
        let kv = g.input(&[1, 1, 2], DType::F32);
        let p = g.input(&[1], DType::I32);
        let up = g.update_at(c, kv, p);
        let cache = Tensor::zeros(&[1, 1, 3, 2]);
        let kvt = t(&[1, 1, 2], vec![1., 2.]);
        let pos = IntTensor::from_vec(&[1], vec![0]);
        let got = run1(&g, up, &[Feed::F32(&cache), Feed::F32(&kvt), Feed::I32(&pos)]);
        assert_eq!(got.data, vec![1., 2., 0., 0., 0., 0.]);
        assert!(cache.data.iter().all(|&x| x == 0.0), "borrowed cache untouched");
    }

    #[test]
    fn update_rows_writes_and_steals_owned_table() {
        // paged-pool write: owned (R, D) table updated in place; duplicate
        // positions resolve to the highest batch index (parked-slot rule)
        let mut g = Graph::default();
        let tb = g.input(&[4, 2], DType::F32);
        let up = g.input(&[3, 2], DType::F32);
        let p = g.input(&[3], DType::I32);
        let w = g.update_rows(tb, up, p);
        let plan = ExecPlan::new(&g, &[w]);
        let table = Tensor::zeros(&[4, 2]);
        let ptr = table.data.as_ptr();
        let upd = t(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let pos = IntTensor::from_vec(&[3], vec![2, 0, 2]);
        let mut args = vec![
            Arg::from_value(Value::F32(table)),
            Arg::F32(&upd),
            Arg::I32(&pos),
        ];
        let out = g.eval_plan(&mut args, &plan, &mut Arena::new()).unwrap();
        let Value::F32(got) = &out[0] else { panic!("expected f32") };
        assert_eq!(got.data, vec![3., 4., 0., 0., 5., 6., 0., 0.]);
        assert_eq!(got.data.as_ptr(), ptr, "table must be updated in place");
    }

    #[test]
    fn update_rows_rejects_out_of_range_position() {
        let mut g = Graph::default();
        let tb = g.input(&[2, 1], DType::F32);
        let up = g.input(&[1, 1], DType::F32);
        let p = g.input(&[1], DType::I32);
        let w = g.update_rows(tb, up, p);
        let table = Tensor::zeros(&[2, 1]);
        let upd = t(&[1, 1], vec![7.]);
        let pos = IntTensor::from_vec(&[1], vec![2]);
        let err = g
            .eval(&[Feed::F32(&table), Feed::F32(&upd), Feed::I32(&pos)], &[w])
            .unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn gather_blocks_reassembles_window_from_block_table() {
        // pool of 3 blocks × 2 slots × (2 heads × 1 dh); a table [2, 0]
        // must produce the window [block2 slots, block0 slots] per head
        let (nb, bl, heads, dh) = (3usize, 2usize, 2usize, 1usize);
        let width = heads * dh;
        let mut g = Graph::default();
        let pool = g.input(&[nb * bl, width], DType::F32);
        let idx = g.input(&[1, 2], DType::I32);
        let out = g.gather_blocks(pool, idx, bl, heads);
        assert_eq!(g.shape(out), &[1, heads, 2 * bl, dh][..]);
        // row r holds [h0 = 10r, h1 = 10r + 1]
        let pt = t(
            &[nb * bl, width],
            (0..nb * bl * width)
                .map(|i| (10 * (i / width) + i % width) as f32)
                .collect(),
        );
        let it = IntTensor::from_vec(&[1, 2], vec![2, 0]);
        let got = run1(&g, out, &[Feed::F32(&pt), Feed::I32(&it)]);
        // head 0: rows 4,5 (block 2) then 0,1 (block 0); head 1: same + 1
        assert_eq!(got.data, vec![40., 50., 0., 10., 41., 51., 1., 11.]);
    }

    #[test]
    fn gather_blocks_rejects_out_of_range_block() {
        let mut g = Graph::default();
        let pool = g.input(&[4, 1], DType::F32);
        let idx = g.input(&[1, 1], DType::I32);
        let out = g.gather_blocks(pool, idx, 2, 1);
        let pt = Tensor::zeros(&[4, 1]);
        let it = IntTensor::from_vec(&[1, 1], vec![2]);
        let err = g.eval(&[Feed::F32(&pt), Feed::I32(&it)], &[out]).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn inplace_chain_reuses_owned_input_buffer() {
        // x → exp (steals the owned input) → add(e, e) (steals e): the
        // output must still live in the original allocation.
        let mut g = Graph::default();
        let x = g.input(&[4], DType::F32);
        let e = g.exp(x);
        let y = g.add(e, e);
        let plan = ExecPlan::new(&g, &[y]);
        let xt = t(&[4], vec![0.0, 1.0, -1.0, 0.5]);
        let expect: Vec<f32> = xt.data.iter().map(|v| 2.0 * v.exp()).collect();
        let ptr = xt.data.as_ptr();
        let mut args = vec![Arg::from_value(Value::F32(xt))];
        let out = g.eval_plan(&mut args, &plan, &mut Arena::new()).unwrap();
        let Value::F32(got) = &out[0] else { panic!("expected f32") };
        for (a, b) in got.data.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-6, "got {a}, want {b}");
        }
        assert_eq!(got.data.as_ptr(), ptr, "chain must reuse the owned input buffer");
    }

    #[test]
    fn plan_and_arena_are_stable_across_repeated_calls() {
        // same plan + arena across calls (the decode steady state): results
        // must be identical on every iteration even though buffers recycle
        let mut g = Graph::default();
        let a = g.input(&[2, 3], DType::F32);
        let b = g.input(&[3], DType::F32);
        let m = g.mul(a, b); // broadcast path
        let e = g.exp(m); // unary in-place on the dying product
        let s = g.reduce_sum(e, 1);
        let plan = ExecPlan::new(&g, &[s]);
        let mut arena = Arena::new();
        let at = t(&[2, 3], vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6]);
        let bt = t(&[3], vec![1.0, 2.0, 3.0]);
        let mut first: Option<Vec<f32>> = None;
        for _ in 0..3 {
            let mut args = vec![Arg::F32(&at), Arg::F32(&bt)];
            let out = g.eval_plan(&mut args, &plan, &mut arena).unwrap();
            let v = out[0].to_f32_tensor();
            match &first {
                None => first = Some(v.data.clone()),
                Some(fst) => assert_eq!(&v.data, fst, "recycled buffers changed the result"),
            }
        }
    }

    #[test]
    fn borrowed_weights_are_not_consumed_across_steps() {
        // weights stay borrowed while owned per-step inputs are consumed:
        // the same Arg vector pattern the serving engine uses
        let mut g = Graph::default();
        let w = g.input(&[2, 2], DType::F32);
        let x = g.input(&[2, 2], DType::F32);
        let y = g.matmul(x, w, false, false);
        let z = g.exp(y);
        let plan = ExecPlan::new(&g, &[z]);
        let wt = t(&[2, 2], vec![1., 0., 0., 1.]);
        let mut arena = Arena::new();
        for step in 0..2 {
            let xt = t(&[2, 2], vec![step as f32; 4]);
            let mut args = vec![Arg::F32(&wt), Arg::from_value(Value::F32(xt))];
            let out = g.eval_plan(&mut args, &plan, &mut arena).unwrap();
            let v = out[0].to_f32_tensor();
            let want = (step as f32).exp(); // x·I = x, entries are `step`
            for got in &v.data {
                assert!((got - want).abs() < 1e-6, "step {step}: {got} vs {want}");
            }
        }
        assert_eq!(wt.data, vec![1., 0., 0., 1.]);
    }
}
