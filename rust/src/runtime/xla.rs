//! The XLA/PJRT execution backend (`--features pjrt`): loads the AOT
//! HLO-text artifacts produced by `python/compile/aot.py`, compiles them on
//! the CPU PJRT client, and executes them with device-resident buffers on
//! the serving hot path. This is the original runtime, now one [`Backend`]
//! among two; the build links the `xla` facade crate unless the real
//! bindings are patched in (see rust/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use super::exec::{check_feed, DeviceBuffer, Exe, Executable, Feed, Outputs, Value};
use super::manifest::Manifest;
use super::Backend;
use crate::tensor::Tensor;
use crate::Result;

pub struct XlaBackend {
    client: xla::PjRtClient,
}

impl XlaBackend {
    pub fn new(dir: &Path) -> Result<XlaBackend> {
        if !dir.exists() {
            return Err(crate::anyhow!(
                "artifact dir {dir:?} missing — run `make artifacts` (pjrt backend \
                 executes exported HLO; the default cpu backend needs no artifacts)"
            ));
        }
        let client = xla::PjRtClient::cpu().map_err(|e| crate::anyhow!("{e}"))?;
        Ok(XlaBackend { client })
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&self, dir: &Path, name: &str) -> Result<Exe> {
        let hlo: PathBuf = dir.join(format!("{name}.hlo.txt"));
        let man = dir.join(format!("{name}.manifest.json"));
        let manifest = Manifest::load(&man)?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo.to_str().ok_or_else(|| crate::anyhow!("bad path"))?,
        )
        .map_err(|e| crate::anyhow!("parse {hlo:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| crate::anyhow!("compile {name}: {e}"))?;
        Ok(Exe::new(Box::new(XlaExe { exe, manifest, client: self.client.clone() })))
    }

    fn has(&self, dir: &Path, name: &str) -> bool {
        dir.join(format!("{name}.hlo.txt")).exists()
    }

    fn upload(&self, feed: &Feed) -> Result<DeviceBuffer> {
        feed_to_buffer(&self.client, feed).map(DeviceBuffer::Pjrt)
    }

    fn download(&self, buf: &DeviceBuffer) -> Result<Tensor> {
        match buf {
            DeviceBuffer::Pjrt(b) => buffer_to_tensor(b),
            DeviceBuffer::Host(_) => {
                Err(crate::anyhow!("pjrt backend cannot download a host buffer"))
            }
        }
    }
}

/// One compiled artifact + its manifest on the PJRT client.
pub struct XlaExe {
    exe: xla::PjRtLoadedExecutable,
    manifest: Manifest,
    client: xla::PjRtClient,
}

impl Executable for XlaExe {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, feeds: &HashMap<&str, Feed>) -> Result<Outputs> {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(self.manifest.inputs.len());
        for spec in &self.manifest.inputs {
            let feed = feeds.get(spec.name.as_str()).ok_or_else(|| {
                crate::anyhow!("missing input `{}` for {}", spec.name, self.manifest.name)
            })?;
            check_feed(feed, spec)?;
            args.push(feed_to_literal(feed, &spec.name)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| crate::anyhow!("execute {}: {e}", self.manifest.name))?;
        let replica = &result[0];
        let expected = self.manifest.outputs.len();
        // PJRT either untuples multi-output roots into separate buffers or
        // hands back one tuple buffer; accept both.
        let literals: Vec<xla::Literal> = if replica.len() == expected {
            let mut v = Vec::with_capacity(expected);
            for b in replica {
                v.push(b.to_literal_sync().map_err(|e| crate::anyhow!("fetch: {e}"))?);
            }
            v
        } else if replica.len() == 1 {
            let lit = replica[0]
                .to_literal_sync()
                .map_err(|e| crate::anyhow!("fetch: {e}"))?;
            if expected == 1 {
                vec![lit]
            } else {
                lit.to_tuple().map_err(|e| crate::anyhow!("untuple: {e}"))?
            }
        } else {
            return Err(crate::anyhow!(
                "{}: expected {} outputs, got {} buffers",
                self.manifest.name,
                expected,
                replica.len()
            ));
        };
        if literals.len() != expected {
            return Err(crate::anyhow!(
                "{}: expected {} outputs, got {}",
                self.manifest.name,
                expected,
                literals.len()
            ));
        }
        let mut values = Vec::with_capacity(expected);
        for lit in &literals {
            values.push(Value::F32(literal_to_tensor(lit)?));
        }
        Ok(Outputs::new(self.manifest.outputs.clone(), values))
    }

    fn run_device(&self, args: &[&DeviceBuffer]) -> Result<Vec<DeviceBuffer>> {
        if args.len() != self.manifest.inputs.len() {
            return Err(crate::anyhow!(
                "{}: expected {} buffer args, got {}",
                self.manifest.name,
                self.manifest.inputs.len(),
                args.len()
            ));
        }
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                DeviceBuffer::Pjrt(b) => bufs.push(b),
                DeviceBuffer::Host(_) => {
                    return Err(crate::anyhow!(
                        "{}: host buffer passed to the pjrt backend",
                        self.manifest.name
                    ));
                }
            }
        }
        let mut result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs)
            .map_err(|e| crate::anyhow!("execute_b {}: {e}", self.manifest.name))?;
        let outs = result.swap_remove(0);
        split_output_buffers(&self.client, outs, self.manifest.outputs.len())
            .map(|v| v.into_iter().map(DeviceBuffer::Pjrt).collect())
    }
}

fn feed_to_literal(feed: &Feed, name: &str) -> Result<xla::Literal> {
    let dims: Vec<i64> = feed.shape().iter().map(|&d| d as i64).collect();
    match feed {
        Feed::F32(t) => xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| crate::anyhow!("reshape {name}: {e}")),
        Feed::I32(t) => xla::Literal::vec1(&t.data)
            .reshape(&dims)
            .map_err(|e| crate::anyhow!("reshape {name}: {e}")),
        Feed::Q8(_) => Err(crate::anyhow!(
            "input {name}: packed q8 weights are cpu-backend only (no PJRT int8 path)"
        )),
    }
}

/// Convert a host literal to a Tensor (f32; i32 outputs are converted).
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(|e| crate::anyhow!("shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let ty = lit.ty().map_err(|e| crate::anyhow!("ty: {e}"))?;
    let data: Vec<f32> = match ty {
        xla::ElementType::F32 => lit.to_vec::<f32>().map_err(|e| crate::anyhow!("{e}"))?,
        xla::ElementType::S32 => lit
            .to_vec::<i32>()
            .map_err(|e| crate::anyhow!("{e}"))?
            .into_iter()
            .map(|x| x as f32)
            .collect(),
        other => return Err(crate::anyhow!("unsupported output dtype {other:?}")),
    };
    Ok(Tensor::from_vec(&dims, data))
}

/// Normalize executable outputs to one device buffer per manifest output.
///
/// This build's XLA wrapper tuples multi-output roots into a single buffer;
/// on the CPU plugin "device" memory is host memory, so the decompose +
/// re-upload below is a memcpy, not a transfer. (The default cpu backend
/// never takes this path at all — its executions return one host value per
/// output with no intermediate literal→tensor→buffer hop.)
fn split_output_buffers(
    client: &xla::PjRtClient,
    outs: Vec<xla::PjRtBuffer>,
    expected: usize,
) -> Result<Vec<xla::PjRtBuffer>> {
    if outs.len() == expected {
        return Ok(outs);
    }
    if outs.len() == 1 && expected > 1 {
        let lit = outs[0]
            .to_literal_sync()
            .map_err(|e| crate::anyhow!("fetch tuple: {e}"))?;
        let parts = lit.to_tuple().map_err(|e| crate::anyhow!("untuple: {e}"))?;
        if parts.len() != expected {
            return Err(crate::anyhow!("tuple arity {} != {expected}", parts.len()));
        }
        // buffer_from_host_literal is an async transfer with no await in
        // this wrapper (UAF once the literal drops); decompose through the
        // synchronous host-buffer path, feeding the literal's own storage
        // to the upload without an intermediate Tensor copy.
        return parts
            .into_iter()
            .map(|p| {
                let shape = p.array_shape().map_err(|e| crate::anyhow!("shape: {e}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = p.to_vec::<f32>().map_err(|e| crate::anyhow!("{e}"))?;
                client
                    .buffer_from_host_buffer(&data, &dims, None)
                    .map_err(|e| crate::anyhow!("upload: {e}"))
            })
            .collect();
    }
    Err(crate::anyhow!("got {} output buffers, expected {expected}", outs.len()))
}

/// Upload a host feed to a device buffer.
pub fn feed_to_buffer(client: &xla::PjRtClient, feed: &Feed) -> Result<xla::PjRtBuffer> {
    match feed {
        Feed::F32(t) => client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| crate::anyhow!("upload: {e}")),
        Feed::I32(t) => client
            .buffer_from_host_buffer(&t.data, &t.shape, None)
            .map_err(|e| crate::anyhow!("upload: {e}")),
        Feed::Q8(_) => Err(crate::anyhow!(
            "packed q8 weights are cpu-backend only (no PJRT int8 path)"
        )),
    }
}

/// Download a device buffer to a host Tensor.
pub fn buffer_to_tensor(buf: &xla::PjRtBuffer) -> Result<Tensor> {
    let lit = buf.to_literal_sync().map_err(|e| crate::anyhow!("fetch: {e}"))?;
    literal_to_tensor(&lit)
}
