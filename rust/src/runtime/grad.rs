//! Reverse-mode autodiff over the interpreter IR: given a scalar loss node,
//! append the gradient subgraph for a chosen set of differentiable inputs
//! (the `jax.value_and_grad` role for the AOT train/mask/LoRA graphs).
//!
//! Coverage matches what the exported graphs need. `ReduceMax` has no VJP
//! on purpose — the graph builders only use it behind `StopGrad` (softmax /
//! logsumexp shifts), which is mathematically exact there.

use super::interp::{Graph, Id, Op};
use crate::tensor::Tensor;

/// Append gradient nodes of `loss` (a scalar node) w.r.t. each id in `wrt`;
/// returns the gradient node ids in `wrt` order. Ids not on any
/// differentiable path get an explicit zeros node of matching shape.
pub fn append_gradients(g: &mut Graph, loss: Id, wrt: &[Id]) -> Vec<Id> {
    assert!(
        g.shape(loss).iter().product::<usize>() == 1,
        "loss must be scalar, got {:?}",
        g.shape(loss)
    );

    // Forward closure: nodes whose value depends on some wrt id.
    let n_fwd = g.nodes.len();
    let mut needs = vec![false; n_fwd];
    for &w in wrt {
        needs[w] = true;
    }
    for id in 0..n_fwd {
        if needs[id] {
            continue;
        }
        if matches!(g.nodes[id].op, Op::StopGrad(_)) {
            continue; // gradient barrier
        }
        if g.nodes[id].op.operands().iter().any(|&o| needs[o]) {
            needs[id] = true;
        }
    }
    assert!(
        needs[loss],
        "loss does not depend on any requested gradient input"
    );

    // Adjoint accumulation, reverse topological order (ids are topo-sorted).
    let mut adj: Vec<Option<Id>> = vec![None; n_fwd];
    let ones = g.constant(Tensor::from_vec(&[], vec![1.0]));
    let loss_shape = g.shape(loss).to_vec();
    adj[loss] = Some(if loss_shape.is_empty() {
        ones
    } else {
        g.broadcast(ones, &loss_shape)
    });

    for id in (0..=loss).rev() {
        let Some(gid) = adj[id] else { continue };
        if !needs[id] {
            continue;
        }
        let op = g.nodes[id].op.clone();
        match op {
            Op::Input(_) | Op::Const(_) | Op::Iota { .. } => {}
            Op::StopGrad(_) => {}
            Op::Neg(x) => {
                let c = g.neg(gid);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Exp(x) => {
                // y = exp(x) is node `id`
                let c = g.mul(gid, id);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Log(x) => {
                let c = g.div(gid, x);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Sqrt(x) => {
                // d/dx sqrt = 0.5 / y
                let half = g.scalar(0.5);
                let t = g.div(gid, id);
                let c = g.mul(half, t);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Rsqrt(x) => {
                // y = x^{-1/2}; dy/dx = -0.5 x^{-3/2} = -0.5 y^3
                let y2 = g.mul(id, id);
                let y3 = g.mul(y2, id);
                let mh = g.scalar(-0.5);
                let t = g.mul(mh, y3);
                let c = g.mul(gid, t);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Tanh(x) => {
                // 1 - y^2
                let y2 = g.mul(id, id);
                let one = g.scalar(1.0);
                let t = g.sub(one, y2);
                let c = g.mul(gid, t);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Sigmoid(x) => {
                // y (1 - y)
                let one = g.scalar(1.0);
                let om = g.sub(one, id);
                let t = g.mul(id, om);
                let c = g.mul(gid, t);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Cos(x) => {
                let s = g.sin(x);
                let ns = g.neg(s);
                let c = g.mul(gid, ns);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Sin(x) => {
                let cs = g.cos(x);
                let c = g.mul(gid, cs);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::CastF32(_) => {} // integer inputs are not differentiable
            Op::Add(a, b) => {
                if needs[a] {
                    let ca = unbroadcast(g, gid, a);
                    acc(g, &mut adj, &needs, a, ca);
                }
                if needs[b] {
                    let cb = unbroadcast(g, gid, b);
                    acc(g, &mut adj, &needs, b, cb);
                }
            }
            Op::Sub(a, b) => {
                if needs[a] {
                    let ca = unbroadcast(g, gid, a);
                    acc(g, &mut adj, &needs, a, ca);
                }
                if needs[b] {
                    let ng = g.neg(gid);
                    let cb = unbroadcast(g, ng, b);
                    acc(g, &mut adj, &needs, b, cb);
                }
            }
            Op::Mul(a, b) => {
                if needs[a] {
                    let t = g.mul(gid, b);
                    let c = unbroadcast(g, t, a);
                    acc(g, &mut adj, &needs, a, c);
                }
                if needs[b] {
                    let t = g.mul(gid, a);
                    let c = unbroadcast(g, t, b);
                    acc(g, &mut adj, &needs, b, c);
                }
            }
            Op::Div(a, b) => {
                if needs[a] {
                    let t = g.div(gid, b);
                    let c = unbroadcast(g, t, a);
                    acc(g, &mut adj, &needs, a, c);
                }
                if needs[b] {
                    // -g·a / b²
                    let num = g.mul(gid, a);
                    let b2 = g.mul(b, b);
                    let t = g.div(num, b2);
                    let nt = g.neg(t);
                    let c = unbroadcast(g, nt, b);
                    acc(g, &mut adj, &needs, b, c);
                }
            }
            Op::Maximum(a, b) => {
                // subgradient: route to the larger side (ties go to `a`)
                let m = g.less(a, b); // 1 where a < b
                if needs[a] {
                    let one = g.scalar(1.0);
                    let inv = g.sub(one, m);
                    let t = g.mul(gid, inv);
                    let c = unbroadcast(g, t, a);
                    acc(g, &mut adj, &needs, a, c);
                }
                if needs[b] {
                    let t = g.mul(gid, m);
                    let c = unbroadcast(g, t, b);
                    acc(g, &mut adj, &needs, b, c);
                }
            }
            Op::Less(_, _) => {} // piecewise-constant mask
            Op::Matmul { a, b, ta, tb } => {
                if needs[a] {
                    // dA' = g·B'ᵀ, transposed back if ta
                    let c = if ta {
                        g.matmul(b, gid, tb, true)
                    } else {
                        g.matmul(gid, b, false, !tb)
                    };
                    acc(g, &mut adj, &needs, a, c);
                }
                if needs[b] {
                    let c = if tb {
                        g.matmul(gid, a, true, ta)
                    } else {
                        g.matmul(a, gid, !ta, false)
                    };
                    acc(g, &mut adj, &needs, b, c);
                }
            }
            Op::Bmm { a, b, ta, tb } => {
                if needs[a] {
                    let c = if ta {
                        g.bmm(b, gid, tb, true)
                    } else {
                        g.bmm(gid, b, false, !tb)
                    };
                    acc(g, &mut adj, &needs, a, c);
                }
                if needs[b] {
                    let c = if tb {
                        g.bmm(gid, a, true, ta)
                    } else {
                        g.bmm(a, gid, !ta, false)
                    };
                    acc(g, &mut adj, &needs, b, c);
                }
            }
            Op::Reshape(x, _) => {
                let xs = g.shape(x).to_vec();
                let c = g.reshape(gid, &xs);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Transpose(x, perm) => {
                let mut inv = vec![0usize; perm.len()];
                for (d, &p) in perm.iter().enumerate() {
                    inv[p] = d;
                }
                let c = g.transpose(gid, &inv);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Broadcast(x, _) => {
                let c = unbroadcast(g, gid, x);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::Concat(xs, axis) => {
                let mut start = 0usize;
                for &x in &xs {
                    let len = g.shape(x)[axis];
                    if needs[x] {
                        let c = g.slice(gid, axis, start, len);
                        acc(g, &mut adj, &needs, x, c);
                    }
                    start += len;
                }
            }
            Op::Slice { x, axis, start, .. } => {
                let full = g.shape(x)[axis];
                let c = g.pad_zero(gid, axis, start, full);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::PadZero { x, axis, start, .. } => {
                let len = g.shape(x)[axis];
                let c = g.slice(gid, axis, start, len);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::ReduceSum(x, axis) => {
                // g (shape minus axis) → keepdims → broadcast to input
                let xs = g.shape(x).to_vec();
                let mut keep = xs.clone();
                keep[axis] = 1;
                let r = g.reshape(gid, &keep);
                let c = g.broadcast(r, &xs);
                acc(g, &mut adj, &needs, x, c);
            }
            Op::ReduceMax(_, _) => {
                panic!("no VJP for ReduceMax: wrap the max in stop_grad (softmax shift)")
            }
            Op::Gather { table, idx } => {
                if needs[table] {
                    let rows = g.shape(table)[0];
                    let c = g.scatter_add_rows(idx, gid, rows);
                    acc(g, &mut adj, &needs, table, c);
                }
            }
            Op::TakeLast { x, idx } => {
                if needs[x] {
                    let n = *g.shape(x).last().unwrap();
                    let c = g.scatter_last(idx, gid, n);
                    acc(g, &mut adj, &needs, x, c);
                }
            }
            Op::ScatterAddRows { .. }
            | Op::ScatterLast { .. }
            | Op::UpdateAt { .. }
            | Op::UpdateRows { .. }
            | Op::GatherBlocks { .. } => {
                panic!("no VJP for scatter/paged-KV ops (serving/adjoint-only)")
            }
            Op::MatmulQ { .. } => {
                panic!("no VJP for quantized matmul (serving-only)")
            }
        }
    }

    wrt.iter()
        .map(|&w| {
            adj[w].unwrap_or_else(|| {
                let shape = g.shape(w).to_vec();
                g.constant(Tensor::zeros(&shape))
            })
        })
        .collect()
}

/// Accumulate contribution `c` into the adjoint of `target`.
fn acc(g: &mut Graph, adj: &mut [Option<Id>], needs: &[bool], target: Id, c: Id) {
    if !needs[target] {
        return;
    }
    adj[target] = Some(match adj[target] {
        None => c,
        Some(prev) => g.add(prev, c),
    });
}

/// Reduce a gradient of broadcast shape back to the shape of node `target`
/// (sum over expanded axes, then reshape to the exact target shape).
fn unbroadcast(g: &mut Graph, grad: Id, target: Id) -> Id {
    let ts = g.shape(target).to_vec();
    let gs = g.shape(grad).to_vec();
    if ts == gs {
        return grad;
    }
    let mut cur = grad;
    // sum away extra leading axes
    while g.shape(cur).len() > ts.len() {
        cur = g.reduce_sum(cur, 0);
    }
    // sum axes where the target had size 1 (right-aligned now)
    let cs = g.shape(cur).to_vec();
    for d in 0..ts.len() {
        if ts[d] == 1 && cs[d] != 1 {
            cur = g.reduce_sum_keep(cur, d);
        }
    }
    if g.shape(cur) != ts.as_slice() {
        cur = g.reshape(cur, &ts);
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::exec::Feed;
    use crate::runtime::interp::DType;
    use crate::tensor::IntTensor;

    /// Evaluate loss + grads for a graph with a single f32 input.
    fn loss_and_grad(g: &Graph, loss: Id, grads: &[Id], x: &Tensor) -> (f32, Vec<Tensor>) {
        let mut outs = vec![loss];
        outs.extend_from_slice(grads);
        let vals = g.eval(&[Feed::F32(x)], &outs).unwrap();
        let l = vals[0].to_f32_tensor().data[0];
        let gs = vals[1..].iter().map(|v| v.to_f32_tensor()).collect();
        (l, gs)
    }

    /// Central finite differences against the autodiff gradient.
    fn finite_diff_check(build: impl Fn(&mut Graph, Id) -> Id, x0: Tensor, tol: f32) {
        let mut g = Graph::default();
        let x = g.input(&x0.shape, DType::F32);
        let loss = build(&mut g, x);
        let grads = append_gradients(&mut g, loss, &[x]);
        let (_, gs) = loss_and_grad(&g, loss, &grads, &x0);
        let analytic = &gs[0];
        let h = 1e-2f32;
        for i in 0..x0.data.len() {
            let mut xp = x0.clone();
            xp.data[i] += h;
            let mut xm = x0.clone();
            xm.data[i] -= h;
            let (lp, _) = loss_and_grad(&g, loss, &[], &xp);
            let (lm, _) = loss_and_grad(&g, loss, &[], &xm);
            let fd = (lp - lm) / (2.0 * h);
            let ad = analytic.data[i];
            assert!(
                (fd - ad).abs() <= tol * (1.0 + fd.abs().max(ad.abs())),
                "coord {i}: fd {fd} vs autodiff {ad}"
            );
        }
    }

    #[test]
    fn grad_of_quadratic_chain() {
        // loss = sum((x*x + 3x) * 0.5)
        finite_diff_check(
            |g, x| {
                let x2 = g.mul(x, x);
                let three = g.scalar(3.0);
                let tx = g.mul(three, x);
                let s = g.add(x2, tx);
                let half = g.scalar(0.5);
                let s2 = g.mul(s, half);
                let flat = g.reshape(s2, &[6]);
                g.reduce_sum(flat, 0)
            },
            Tensor::from_vec(&[2, 3], vec![0.5, -1.0, 2.0, 0.3, 1.5, -0.7]),
            1e-2,
        );
    }

    #[test]
    fn grad_through_matmul_and_transcendentals() {
        // loss = sum(sigmoid(x @ c) + exp(-x @ c))
        let c = Tensor::from_vec(&[3, 2], vec![0.5, -0.2, 0.1, 0.4, -0.3, 0.25]);
        finite_diff_check(
            move |g, x| {
                let cc = g.constant(c.clone());
                let y = g.matmul(x, cc, false, false);
                let s = g.sigmoid(y);
                let ny = g.neg(y);
                let e = g.exp(ny);
                let t = g.add(s, e);
                let flat = g.reshape(t, &[4]);
                g.reduce_sum(flat, 0)
            },
            Tensor::from_vec(&[2, 3], vec![0.2, -0.4, 0.6, 1.0, -0.8, 0.1]),
            1e-2,
        );
    }

    #[test]
    fn grad_through_softmax_style_block() {
        // mean of softmax-weighted values — exercises stop_grad(max), exp,
        // div, reduce, broadcast paths exactly like the attention graph
        finite_diff_check(
            |g, x| {
                let m = g.reduce_max_keep(x, 1);
                let ms = g.stop_grad(m);
                let sh = g.sub(x, ms);
                let e = g.exp(sh);
                let s = g.reduce_sum_keep(e, 1);
                let p = g.div(e, s);
                let w = g.iota(4); // weights 0..3
                let pw = g.mul(p, w);
                let flat = g.reshape(pw, &[8]);
                g.reduce_sum(flat, 0)
            },
            Tensor::from_vec(&[2, 4], vec![0.1, 0.5, -0.3, 0.8, 1.2, -0.5, 0.0, 0.4]),
            1e-2,
        );
    }

    #[test]
    fn grad_through_rmsnorm_block() {
        // y = x * rsqrt(mean(x²)+eps) * gain; loss = sum(y)
        let gain = Tensor::from_vec(&[3], vec![1.0, 0.5, 2.0]);
        finite_diff_check(
            move |g, x| {
                let x2 = g.mul(x, x);
                let ssum = g.reduce_sum_keep(x2, 1);
                let third = g.scalar(1.0 / 3.0);
                let ms = g.mul(ssum, third);
                let eps = g.scalar(1e-6);
                let mse = g.add(ms, eps);
                let inv = g.rsqrt(mse);
                let xn = g.mul(x, inv);
                let gn = g.constant(gain.clone());
                let y = g.mul(xn, gn);
                let flat = g.reshape(y, &[6]);
                g.reduce_sum(flat, 0)
            },
            Tensor::from_vec(&[2, 3], vec![0.4, -0.9, 1.3, 0.7, 0.2, -1.1]),
            2e-2,
        );
    }

    #[test]
    fn grad_through_gather_is_scatter() {
        // loss = sum(table[idx] * w): d(table) accumulates w rows by index
        let mut g = Graph::default();
        let table = g.input(&[3, 2], DType::F32);
        let idx = g.constant_i32(IntTensor::from_vec(&[2], vec![2, 2]));
        let picked = g.gather(table, idx);
        let flat = g.reshape(picked, &[4]);
        let loss = g.reduce_sum(flat, 0);
        let grads = append_gradients(&mut g, loss, &[table]);
        let tt = Tensor::from_vec(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let out = g.eval(&[Feed::F32(&tt)], &[loss, grads[0]]).unwrap();
        assert_eq!(out[0].to_f32_tensor().data[0], 22.0); // 2×(5+6)
        // both gathers hit row 2 → gradient 2 on row 2, 0 elsewhere
        assert_eq!(out[1].to_f32_tensor().data, vec![0., 0., 0., 0., 2., 2.]);
    }

    #[test]
    fn unbroadcast_sums_expanded_axes() {
        // z = x (2,3) * m (3,): d(m) must sum over the leading axis
        let mut g = Graph::default();
        let x = g.input(&[2, 3], DType::F32);
        let m = g.input(&[3], DType::F32);
        let z = g.mul(x, m);
        let flat = g.reshape(z, &[6]);
        let loss = g.reduce_sum(flat, 0);
        let grads = append_gradients(&mut g, loss, &[m]);
        let xt = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let mt = Tensor::from_vec(&[3], vec![1., 1., 1.]);
        let out = g.eval(&[Feed::F32(&xt), Feed::F32(&mt)], &[grads[0]]).unwrap();
        assert_eq!(out[0].to_f32_tensor().data, vec![5., 7., 9.]);
    }
}
