//! Activation-aware SVD pipeline (paper Sec. 3.1, following SVD-LLM):
//!
//!   1. calibration — accumulate per-module input Gram matrices H = Σ XXᵀ
//!      by running the AOT `calibrate` executable over calibration batches;
//!   2. whitening — H + εI = S·Sᵀ (Cholesky), factor the product W·S;
//!   3. factorization — SVD(W·S) = U·Σ·Vᵀ gives W_u = U√Σ and
//!      W_v = √Σ·Vᵀ·S⁻¹ with W = W_u·W_v exactly at full rank.
//!
//! The whitened singular values δ are kept per module: they drive the
//! truncation loss L_R, the guidance metric G_R (Eq. 6), and several
//! baselines (STRS thresholds, FARMS spectra).

use std::collections::{BTreeMap, HashMap};

use crate::config::ModelCfg;
use crate::data::{batches, corpus_spec, generate_tokens};
use crate::linalg::{cholesky, invert_lower_triangular, svd, Mat};
use crate::model::{module_dims, Allocation, ModuleAlloc, WeightStore};
use crate::runtime::{Feed, Runtime};
use crate::tensor::Tensor;
use crate::Result;

/// Full-rank whitened factorization of one module.
#[derive(Debug, Clone)]
pub struct ModuleFactors {
    /// (m, r) = U·√Σ
    pub wu: Tensor,
    /// (r, n) = √Σ·Vᵀ·S⁻¹
    pub wv: Tensor,
    /// Whitened singular values δ₁ ≥ … ≥ δ_r.
    pub sigma: Vec<f64>,
}

impl ModuleFactors {
    pub fn r_full(&self) -> usize {
        self.sigma.len()
    }

    /// Physically truncated factors (serving / export): (m,k) and (k,n).
    pub fn truncate(&self, k: usize) -> (Tensor, Tensor) {
        (self.wu.left_cols(k), self.wv.top_rows(k))
    }

    /// Truncation loss √(Σ_{i>k} δᵢ²) — the L_R of Sec. 3.3.
    pub fn tail_norm(&self, k: usize) -> f64 {
        self.sigma[k.min(self.sigma.len())..]
            .iter()
            .map(|x| x * x)
            .sum::<f64>()
            .sqrt()
    }

    /// Total output norm L₀ = √(Σ δᵢ²).
    pub fn total_norm(&self) -> f64 {
        self.tail_norm(0)
    }
}

/// All modules' factors + the calibration seed used.
#[derive(Debug, Clone, Default)]
pub struct FactoredModel {
    pub factors: BTreeMap<String, ModuleFactors>,
}

/// Accumulate the per-module Gram matrices over `n_batches` calibration
/// batches (the paper calibrates on C4 → our `sync4`).
pub fn calibrate(
    cfg: &ModelCfg,
    rt: &Runtime,
    ws: &WeightStore,
    corpus: &str,
    n_batches: usize,
    seed: u64,
) -> Result<BTreeMap<String, Mat>> {
    let exe = rt.load("calibrate")?;
    let spec = corpus_spec(corpus);
    let need = n_batches * cfg.batch_eval * (cfg.seq_eval + 1) + 1;
    let stream = generate_tokens(cfg.vocab, spec, seed, need);
    let data = batches(&stream, cfg.batch_eval, cfg.seq_eval);
    let dims = module_dims(cfg);
    let mut acc: BTreeMap<String, Mat> = dims
        .iter()
        .map(|d| (d.name.clone(), Mat::zeros(d.n, d.n)))
        .collect();

    for (toks, _) in data.iter().take(n_batches) {
        let mut feeds: HashMap<&str, Feed> = HashMap::new();
        for (name, t) in &ws.tensors {
            feeds.insert(name.as_str(), Feed::F32(t));
        }
        feeds.insert("tokens", Feed::I32(toks));
        let out = exe.run(&feeds)?;
        for d in &dims {
            let h = out.tensor(&format!("h:{}", d.name))?;
            let a = acc.get_mut(&d.name).unwrap();
            for (dst, &src) in a.data.iter_mut().zip(&h.data) {
                *dst += src as f64;
            }
        }
    }
    Ok(acc)
}

/// Factorize every compressible module given its Gram matrix.
///
/// `damp` is the relative diagonal damping ε/mean(diag) that keeps H
/// positive definite (calibration streams shorter than n would otherwise
/// make H singular).
pub fn factorize(
    cfg: &ModelCfg,
    ws: &WeightStore,
    grams: &BTreeMap<String, Mat>,
    damp: f64,
) -> Result<FactoredModel> {
    let mut fm = FactoredModel::default();
    for d in module_dims(cfg) {
        let w = ws.get(&d.name);
        let h = grams
            .get(&d.name)
            .ok_or_else(|| crate::anyhow!("no gram for {}", d.name))?;
        fm.factors.insert(d.name.clone(), factorize_module(w, h, damp)?);
    }
    Ok(fm)
}

/// Whitened SVD of one module (see module docs).
pub fn factorize_module(w: &Tensor, h: &Mat, damp: f64) -> Result<ModuleFactors> {
    let (m, n) = (w.shape[0], w.shape[1]);
    assert_eq!(h.rows, n);
    // dampen: H + εI
    let mean_diag = (0..n).map(|i| h.at(i, i)).sum::<f64>() / n as f64;
    let eps = (damp * mean_diag).max(1e-10);
    let mut hd = h.clone();
    for i in 0..n {
        let v = hd.at(i, i) + eps;
        hd.set(i, i, v);
    }
    let s = cholesky(&hd)?;
    let s_inv = invert_lower_triangular(&s)?;

    let wmat = Mat::from_f32(m, n, &w.data);
    let ws_prod = wmat.matmul(&s);
    let dec = svd(&ws_prod);
    let r = m.min(n);

    // wu = U √Σ (m, r)
    let mut wu = Mat::zeros(m, r);
    for i in 0..m {
        for j in 0..r {
            wu.set(i, j, dec.u.at(i, j) * dec.s[j].max(0.0).sqrt());
        }
    }
    // wv = √Σ Vᵀ S⁻¹ (r, n)
    let mut sv = Mat::zeros(r, n);
    for i in 0..r {
        let sq = dec.s[i].max(0.0).sqrt();
        for j in 0..n {
            sv.set(i, j, sq * dec.vt.at(i, j));
        }
    }
    let wv = sv.matmul(&s_inv);

    Ok(ModuleFactors {
        wu: Tensor::from_vec(&[m, r], wu.to_f32()),
        wv: Tensor::from_vec(&[r, n], wv.to_f32()),
        sigma: dec.s,
    })
}

/// Binary rank masks for an allocation: Dense ⇒ all ones over r_full (the
/// R ≥ 1 branch of Eq. 8 under the masked-max-rank parameterization),
/// Rank(k) ⇒ ones on the top-k singular directions.
pub fn alloc_masks(cfg: &ModelCfg, alloc: &Allocation) -> BTreeMap<String, Tensor> {
    let mut out = BTreeMap::new();
    for d in module_dims(cfg) {
        let r = d.r_full();
        let mask = match alloc.get(&d.name) {
            ModuleAlloc::Dense => Tensor::ones(&[r]),
            ModuleAlloc::Rank(k) => {
                let mut t = Tensor::zeros(&[r]);
                for i in 0..k.min(r) {
                    t.data[i] = 1.0;
                }
                t
            }
        };
        out.insert(d.name.clone(), mask);
    }
    out
}

/// Build the feed map for a factored-parameterization executable
/// (score_masked / mask_fwd_grad / lora_step): aux weights + factors + masks.
pub fn factored_feeds<'a>(
    ws: &'a WeightStore,
    fm: &'a FactoredModel,
    masks: &'a BTreeMap<String, Tensor>,
    feeds: &mut HashMap<&'a str, Feed<'a>>,
) {
    for (name, t) in &ws.tensors {
        // only aux params exist in the factored spec; compressible dense
        // tensors are superseded by their factors — harmless to skip.
        if fm.factors.contains_key(name) {
            continue;
        }
        feeds.insert(name.as_str(), Feed::F32(t));
    }
    for (name, f) in &fm.factors {
        // keys "name.u" / "name.v" / "mask:name" must live as long as 'a:
        // we lean on the fact that manifests own the spec names; the feed
        // map is keyed by &str borrowed from these leaked-in-place strings.
        feeds.insert(intern_key(format!("{name}.u")), Feed::F32(&f.wu));
        feeds.insert(intern_key(format!("{name}.v")), Feed::F32(&f.wv));
    }
    for (name, m) in masks {
        feeds.insert(intern_key(format!("mask:{name}")), Feed::F32(m));
    }
}

/// Intern feed keys: module-name-derived keys are a small closed set, so a
/// process-lifetime intern table avoids per-call allocation churn without
/// unbounded leaking.
pub(crate) fn intern_key(s: String) -> &'static str {
    use std::collections::HashSet;
    use std::sync::{Mutex, OnceLock};
    static INTERN: OnceLock<Mutex<HashSet<&'static str>>> = OnceLock::new();
    let table = INTERN.get_or_init(|| Mutex::new(HashSet::new()));
    let mut guard = table.lock().unwrap();
    if let Some(&k) = guard.get(s.as_str()) {
        return k;
    }
    let k: &'static str = Box::leak(s.into_boxed_str());
    guard.insert(k);
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;

    fn random_tensor(rng: &mut Rng, m: usize, n: usize) -> Tensor {
        Tensor::from_vec(
            &[m, n],
            (0..m * n).map(|_| rng.normal() as f32 * 0.1).collect(),
        )
    }

    fn random_gram(rng: &mut Rng, n: usize, samples: usize) -> Mat {
        // H = Σ x xᵀ over `samples` random activations
        let mut h = Mat::zeros(n, n);
        for _ in 0..samples {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            for i in 0..n {
                for j in 0..n {
                    h.data[i * n + j] += x[i] * x[j];
                }
            }
        }
        h
    }

    #[test]
    fn full_rank_factorization_reconstructs_w() {
        let mut rng = Rng::new(3);
        for (m, n) in [(12, 12), (8, 20), (20, 8)] {
            let w = random_tensor(&mut rng, m, n);
            let h = random_gram(&mut rng, n, 4 * n);
            let f = factorize_module(&w, &h, 1e-4).unwrap();
            let back = f.wu.matmul(&f.wv);
            for (a, b) in back.data.iter().zip(&w.data) {
                assert!((a - b).abs() < 1e-3, "({m},{n})");
            }
        }
    }

    #[test]
    fn sigma_sorted_and_tail_monotone() {
        let mut rng = Rng::new(5);
        let w = random_tensor(&mut rng, 10, 14);
        let h = random_gram(&mut rng, 14, 60);
        let f = factorize_module(&w, &h, 1e-4).unwrap();
        for i in 1..f.sigma.len() {
            assert!(f.sigma[i - 1] >= f.sigma[i] - 1e-12);
        }
        for k in 1..f.sigma.len() {
            assert!(f.tail_norm(k) <= f.tail_norm(k - 1) + 1e-12);
        }
        assert!(f.tail_norm(f.sigma.len()) < 1e-12);
    }

    #[test]
    fn truncated_factors_shapes() {
        let mut rng = Rng::new(7);
        let w = random_tensor(&mut rng, 6, 10);
        let h = random_gram(&mut rng, 10, 50);
        let f = factorize_module(&w, &h, 1e-4).unwrap();
        let (u, v) = f.truncate(3);
        assert_eq!(u.shape, vec![6, 3]);
        assert_eq!(v.shape, vec![3, 10]);
    }

    #[test]
    fn singular_gram_is_handled_by_damping() {
        // fewer samples than n ⇒ H rank deficient; damping must save it
        let mut rng = Rng::new(9);
        let w = random_tensor(&mut rng, 6, 16);
        let h = random_gram(&mut rng, 16, 3);
        let f = factorize_module(&w, &h, 1e-2).unwrap();
        let back = f.wu.matmul(&f.wv);
        for (a, b) in back.data.iter().zip(&w.data) {
            assert!((a - b).abs() < 1e-2);
        }
    }

    #[test]
    fn alloc_masks_shapes_and_counts() {
        let paths = crate::config::Paths::discover().unwrap();
        let cfg = crate::config::model_by_name(&paths.configs, "micro-llama").unwrap();
        let mut alloc = Allocation::new("t");
        for d in module_dims(&cfg) {
            alloc.set(&d.name, ModuleAlloc::Rank(d.r_full() / 2));
        }
        let masks = alloc_masks(&cfg, &alloc);
        for d in module_dims(&cfg) {
            let m = &masks[&d.name];
            assert_eq!(m.shape, vec![d.r_full()]);
            let ones: f32 = m.data.iter().sum();
            assert_eq!(ones as usize, d.r_full() / 2);
        }
    }
}
