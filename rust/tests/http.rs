//! Integration tests for the HTTP serving front end (DESIGN.md §7) over a
//! real loopback socket: field-naming validation errors, the streaming ↔
//! non-streaming reassembly contract, disconnect-mid-stream cancellation
//! (KV blocks freed — the worker-side `assert_balanced` leak check runs
//! in the scheduler's debug-build `Drop` when the server joins the router
//! at shutdown), and malformed/oversized bodies refused without touching
//! the scheduler.

use std::io::Read;
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::json::{self, Json};
use ara_compress::serving::http::wire::{http_call, read_response, send_request, send_request_keep};
use ara_compress::serving::{HttpCfg, HttpServer, Router, RouterCfg, ShutdownHandle};

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl
}

/// Launch a full server (engine on the router worker) on a free loopback
/// port. The returned join handle yields `HttpServer::run`'s result — an
/// `Err` after shutdown means the worker panicked, which in these
/// debug-assertion builds includes a tripped KV-pool leak check.
/// Serializes the train-or-load step against the shared disk cache for
/// every server flavor in this file (same pattern as tests/chaos.rs).
static PRETRAIN_LOCK: Mutex<()> = Mutex::new(());

fn start_server(
    cfg: HttpCfg,
) -> (String, ShutdownHandle, std::thread::JoinHandle<ara_compress::Result<()>>) {
    let pl = pipeline();
    let vocab = pl.cfg.vocab;
    let router = Router::spawn_with(RouterCfg { queue_depth: 8, ..RouterCfg::default() }, move || {
        let _guard = PRETRAIN_LOCK.lock().unwrap();
        let ws = pl.pretrained().expect("pretrain substrate");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        pl.engine(&ws, &fm, "uniform-80", 2).expect("engine")
    });
    let server = HttpServer::bind("127.0.0.1:0", router, vocab, cfg).expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());
    (addr, stop, handle)
}

/// Like [`start_server`], but the engine serves a quantized plan
/// (`uniform@0.8?quant=int8&group=32`) built through the pipeline front
/// door — packed int8 factors end-to-end.
fn start_quant_server(
    cfg: HttpCfg,
) -> (String, ShutdownHandle, std::thread::JoinHandle<ara_compress::Result<()>>) {
    let pl = pipeline();
    let vocab = pl.cfg.vocab;
    let router = Router::spawn_with(RouterCfg { queue_depth: 8, ..RouterCfg::default() }, move || {
        let _guard = PRETRAIN_LOCK.lock().unwrap();
        let ws = pl.pretrained().expect("pretrain substrate");
        let grams = pl.grams(&ws).expect("calibrate");
        let fm = pl.factored(&ws, &grams).expect("factorize");
        let plan = pl
            .allocate_spec("uniform@0.8?quant=int8&group=32", &ws, &grams, &fm)
            .expect("quant plan");
        pl.engine_for_plan(&ws, &fm, &plan, 2).expect("quantized engine")
    });
    let server = HttpServer::bind("127.0.0.1:0", router, vocab, cfg).expect("bind loopback");
    let addr = server.local_addr().expect("local addr").to_string();
    let stop = server.shutdown_handle();
    let handle = std::thread::spawn(move || server.run());
    (addr, stop, handle)
}

fn prompt_tokens(n: usize, seed: u64) -> Vec<i32> {
    generate_tokens(256, corpus_spec("synwiki"), seed, n.max(16))[..n].to_vec()
}

fn completion_json(prompt: &[i32], max_tokens: usize, extra: &str) -> String {
    let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
    format!(r#"{{"prompt":[{}],"max_tokens":{max_tokens}{extra}}}"#, toks.join(","))
}

fn stats(addr: &str) -> Json {
    let r = http_call(addr, "GET", "/stats", None).expect("stats call");
    assert_eq!(r.status, 200);
    json::parse(std::str::from_utf8(&r.body).unwrap()).expect("stats json")
}

fn sched_counter(st: &Json, key: &str) -> usize {
    st.req("sched").unwrap().req(key).unwrap().as_usize().unwrap()
}

/// Validation errors carry the offending field by name; malformed and
/// oversized bodies get 400 before the scheduler sees anything (pinned
/// via the `/stats` counters afterwards). Routes answer 404/405 typed.
#[test]
fn validation_errors_name_fields_and_never_touch_the_scheduler() {
    let cfg = HttpCfg { max_body_bytes: 2048, ..HttpCfg::default() };
    let (addr, stop, server) = start_server(cfg);

    let cases: &[(&str, &str)] = &[
        (r#"{"prompt":[1,2]}"#, "max_tokens"),
        (r#"{"max_tokens":4,"prompt":"hi"}"#, "prompt"),
        (r#"{"max_tokens":4,"prompt":[999]}"#, "prompt"),
        (r#"{"max_tokens":4,"stream":"yes"}"#, "stream"),
        (r#"{"max_tokens":4,"best_of":2}"#, "best_of"),
        (r#"{"max_tokens":4,"timeout_steps":0}"#, "timeout_steps"),
        ("this is not json", "body"),
    ];
    for (body, field) in cases {
        let r = http_call(&addr, "POST", "/v1/completions", Some(body)).expect("call");
        assert_eq!(r.status, 400, "`{body}` must be refused");
        let j = json::parse(std::str::from_utf8(&r.body).unwrap()).expect("error json");
        let e = j.req("error").expect("structured error");
        assert_eq!(
            e.req("field").unwrap().as_str().unwrap(),
            *field,
            "`{body}` must name the offending field"
        );
    }

    // oversized: the declared length alone gets the request refused
    let huge = completion_json(&vec![1; 4096], 4, "");
    assert!(huge.len() > 2048);
    let r = http_call(&addr, "POST", "/v1/completions", Some(&huge)).expect("call");
    assert_eq!(r.status, 400, "oversized body must be refused");

    // unknown route and wrong method
    let r = http_call(&addr, "GET", "/v2/nope", None).expect("call");
    assert_eq!(r.status, 404);
    let r = http_call(&addr, "GET", "/v1/completions", None).expect("call");
    assert_eq!(r.status, 405);

    // none of the above ever reached the scheduler
    let st = stats(&addr);
    assert_eq!(sched_counter(&st, "admitted"), 0, "scheduler must be untouched");
    assert_eq!(sched_counter(&st, "completed"), 0);
    assert_eq!(st.req("in_flight").unwrap().as_usize().unwrap(), 0);

    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}

/// `GET /stats` carries the composed compression recipe: the `plan`
/// object's `quant` is null for an f32 engine and `{bits, group}` for a
/// quantized one — and the quantized server completes requests end-to-end
/// over the wire (DESIGN.md §9).
#[test]
fn stats_plan_object_reports_quant_recipe() {
    // f32 engine: plan.quant must be null
    let (addr, stop, server) = start_server(HttpCfg::default());
    let st = stats(&addr);
    let plan = st.req("plan").expect("stats must carry a plan object");
    assert!(
        matches!(plan.req("quant").unwrap(), Json::Null),
        "f32 plan must report quant: null, got {}",
        plan.dump()
    );
    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");

    // quantized engine: plan.quant carries the recipe, provenance names it,
    // and completions still serve
    let (addr, stop, server) = start_quant_server(HttpCfg::default());
    let st = stats(&addr);
    let plan = st.req("plan").expect("plan object");
    let q = plan.req("quant").expect("quant key");
    assert_eq!(q.req("bits").unwrap().as_usize().unwrap(), 8, "{}", plan.dump());
    assert_eq!(q.req("group").unwrap().as_usize().unwrap(), 32, "{}", plan.dump());
    let prov = plan.req("provenance").unwrap().as_str().expect("provenanced plan");
    assert!(prov.contains("int8/g32"), "provenance must name the recipe: {prov}");

    let body = completion_json(&prompt_tokens(5, 4242), 6, "");
    let r = http_call(&addr, "POST", "/v1/completions", Some(&body)).expect("quant completion");
    assert_eq!(r.status, 200);
    let j = json::parse(std::str::from_utf8(&r.body).unwrap()).expect("completion json");
    assert_eq!(j.req("finish_reason").unwrap().as_str().unwrap(), "stop");
    assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 6);
    // greedy decode over packed weights is deterministic over the wire
    let again = http_call(&addr, "POST", "/v1/completions", Some(&body)).expect("repeat");
    assert_eq!(again.body, r.body, "quantized completions must be byte-identical");

    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}

/// The reassembly contract: a streamed completion's token chunks parse to
/// exactly the non-streaming token array, and its final chunk is
/// byte-identical to the whole non-streaming body. Greedy requests are
/// also byte-identical across repeat runs (determinism over the wire).
#[test]
fn streaming_chunks_reassemble_to_the_non_streaming_body() {
    let (addr, stop, server) = start_server(HttpCfg::default());
    let body = completion_json(&prompt_tokens(5, 4242), 6, "");

    let plain = http_call(&addr, "POST", "/v1/completions", Some(&body)).expect("plain call");
    assert_eq!(plain.status, 200);
    assert!(plain.chunks.is_none(), "non-streaming must be identity-framed");
    let j = json::parse(std::str::from_utf8(&plain.body).unwrap()).expect("completion json");
    assert_eq!(j.req("finish_reason").unwrap().as_str().unwrap(), "stop");
    let want: Vec<i64> = j
        .req("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i64)
        .collect();
    assert_eq!(want.len(), 6);

    let streamed_body = completion_json(&prompt_tokens(5, 4242), 6, r#","stream":true"#);
    let streamed =
        http_call(&addr, "POST", "/v1/completions", Some(&streamed_body)).expect("stream call");
    assert_eq!(streamed.status, 200);
    let chunks = streamed.chunks.expect("streaming must be chunked");
    assert_eq!(chunks.len(), want.len() + 1, "one chunk per token + the final body");
    let got: Vec<i64> = chunks[..want.len()]
        .iter()
        .map(|c| {
            let j = json::parse(std::str::from_utf8(c).unwrap().trim()).expect("token chunk");
            j.req("token").unwrap().as_f64().unwrap() as i64
        })
        .collect();
    assert_eq!(got, want, "streamed tokens must reassemble to the response array");
    assert_eq!(
        chunks.last().unwrap(),
        &plain.body,
        "the final chunk must be byte-identical to the non-streaming body"
    );

    // run-to-run determinism of the full body, greedy over the wire
    let again = http_call(&addr, "POST", "/v1/completions", Some(&body)).expect("repeat call");
    assert_eq!(again.status, 200);
    assert_eq!(again.body, plain.body, "greedy completions must be byte-identical");

    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}

/// Disconnecting mid-stream trips the request's cancel token: the
/// scheduler completes it `Cancelled` and frees its slot and KV blocks.
/// The block accounting is then proven twice — live via `/stats`
/// (`used_blocks` back to zero with no prefix cache on this path's
/// cancelled chain) and at shutdown, where the debug-build
/// `assert_balanced` leak check runs in the worker's scheduler `Drop` and
/// would fail `HttpServer::run` on any leak.
#[test]
fn disconnect_mid_stream_cancels_and_frees_blocks() {
    let (addr, stop, server) = start_server(HttpCfg::default());
    // long request: ~40 decode steps of runway after the first chunk
    let body = completion_json(&prompt_tokens(6, 777), 40, r#","stream":true"#);

    let mut raw = TcpStream::connect(&addr).expect("connect");
    send_request(&mut raw, "POST", "/v1/completions", Some(&body)).expect("send");
    // read just past the response head (written with the first token),
    // then vanish — the handler's next peek sees EOF and cancels
    raw.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut seen = Vec::new();
    let mut buf = [0u8; 256];
    while !seen.windows(4).any(|w| w == b"\r\n\r\n") {
        let n = raw.read(&mut buf).expect("response head");
        assert!(n > 0, "server closed before streaming started");
        seen.extend_from_slice(&buf[..n]);
    }
    assert!(seen.starts_with(b"HTTP/1.1 200"), "stream must have started");
    drop(raw);

    // the cancellation lands at a step boundary; poll the public surface
    let t0 = Instant::now();
    loop {
        let st = stats(&addr);
        // the admission slot releases one handler-turn after the counter
        // ticks — require both before declaring the request fully gone
        if sched_counter(&st, "cancelled") == 1
            && st.req("in_flight").unwrap().as_usize().unwrap() == 0
        {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "disconnect was never converted into a cancellation; stats: {}",
            st.dump()
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // clean shutdown runs the worker-side KV leak check (assert_balanced
    // in the scheduler's Drop) — a leaked block fails the join
    stop.shutdown();
    server.join().expect("server thread").expect("no leaked KV blocks at shutdown");
}

/// Keep-alive: one TCP connection serves sequential requests with bodies
/// byte-identical to one-shot connections; a streamed completion on the
/// same connection closes it after the terminal chunk (streaming is tied
/// to the decode loop, so reuse would serialize unrelated requests).
#[test]
fn keep_alive_reuses_the_connection_with_identical_bodies() {
    let (addr, stop, server) = start_server(HttpCfg::default());
    let body = completion_json(&prompt_tokens(5, 9090), 5, "");

    // reference bodies over one-shot connections
    let oneshot = http_call(&addr, "POST", "/v1/completions", Some(&body)).expect("one-shot");
    assert_eq!(oneshot.status, 200);

    let mut raw = TcpStream::connect(&addr).expect("connect");
    raw.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    // mixed traffic over ONE connection: health, two completions, stats
    send_request_keep(&mut raw, "GET", "/healthz", None, true).expect("send healthz");
    let r = read_response(&mut raw).expect("healthz response");
    assert_eq!(r.status, 200);
    send_request_keep(&mut raw, "POST", "/v1/completions", Some(&body), true).expect("send 1");
    let first = read_response(&mut raw).expect("first completion");
    send_request_keep(&mut raw, "POST", "/v1/completions", Some(&body), true).expect("send 2");
    let second = read_response(&mut raw).expect("second completion");
    assert_eq!(first.status, 200);
    assert_eq!(first.body, second.body, "keep-alive repeats must be byte-identical");
    assert_eq!(first.body, oneshot.body, "keep-alive must not change response bodies");

    // a streamed completion on the same connection answers chunked and
    // then closes it, even though the client asked keep-alive
    let streamed_body = completion_json(&prompt_tokens(5, 9090), 5, r#","stream":true"#);
    send_request_keep(&mut raw, "POST", "/v1/completions", Some(&streamed_body), true)
        .expect("send stream");
    let streamed = read_response(&mut raw).expect("streamed response");
    assert_eq!(streamed.status, 200);
    assert!(streamed.chunks.is_some());
    assert_eq!(
        streamed.chunks.as_ref().unwrap().last().unwrap(),
        &oneshot.body,
        "final chunk still byte-identical to the non-streaming body"
    );
    let mut probe = [0u8; 16];
    assert_eq!(
        raw.read(&mut probe).expect("post-stream read"),
        0,
        "server must close the connection after a streamed response"
    );

    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}

/// `ARA_HTTP_KEEPALIVE_MAX = 1` disables reuse: the server answers with
/// `Connection: close` framing and hangs up after one request even when
/// the client asked keep-alive.
#[test]
fn keepalive_max_one_closes_after_every_request() {
    let (addr, stop, server) =
        start_server(HttpCfg { keepalive_max: 1, ..HttpCfg::default() });
    let mut raw = TcpStream::connect(&addr).expect("connect");
    raw.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    send_request_keep(&mut raw, "GET", "/healthz", None, true).expect("send");
    let r = read_response(&mut raw).expect("response");
    assert_eq!(r.status, 200);
    let mut probe = [0u8; 16];
    assert_eq!(
        raw.read(&mut probe).expect("post-response read"),
        0,
        "keepalive_max = 1 must close after the first response"
    );
    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}

/// The accept-loop connection cap: with `max_conns = 1` and one held
/// keep-alive connection, a second connection is shed with an immediate
/// 503 — no handler thread, no engine work. Releasing the held connection
/// restores service.
#[test]
fn connection_cap_sheds_excess_with_503() {
    let (addr, stop, server) =
        start_server(HttpCfg { max_conns: 1, ..HttpCfg::default() });

    // hold the only slot: a completed keep-alive request leaves the
    // handler thread alive, parked in read_request
    let mut held = TcpStream::connect(&addr).expect("connect");
    held.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    send_request_keep(&mut held, "GET", "/healthz", None, true).expect("send");
    assert_eq!(read_response(&mut held).expect("held response").status, 200);

    // the next connection must be shed at accept time
    let shed = http_call(&addr, "GET", "/healthz", None).expect("shed call");
    assert_eq!(shed.status, 503, "over-cap connection must get an immediate 503");
    let j = json::parse(std::str::from_utf8(&shed.body).unwrap()).expect("503 body json");
    assert_eq!(j.req("error").unwrap().req("type").unwrap().as_str().unwrap(), "server_error");

    // release the slot; the handler thread exits once the peer vanishes
    drop(held);
    let t0 = Instant::now();
    loop {
        if let Ok(r) = http_call(&addr, "GET", "/healthz", None) {
            if r.status == 200 {
                break;
            }
        }
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "slot never freed after the held connection dropped"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}

/// A `timeout_steps` deadline and admission shedding surface as their
/// mapped statuses (408 / 429) with typed bodies — the fix satellite's
/// wire-visible half (the unit mapping itself is pinned in
/// `serving::http::types`).
#[test]
fn deadline_and_shed_map_to_distinct_statuses() {
    let (addr, stop, server) = start_server(HttpCfg::default());

    // warm the engine so the deadline request's steps are all decode
    let warm = completion_json(&prompt_tokens(4, 31), 2, "");
    let r = http_call(&addr, "POST", "/v1/completions", Some(&warm)).expect("warm call");
    assert_eq!(r.status, 200);

    // a 1-step budget cannot cover a 24-token generation → 408
    let doomed = completion_json(&prompt_tokens(6, 32), 24, r#","timeout_steps":1"#);
    let r = http_call(&addr, "POST", "/v1/completions", Some(&doomed)).expect("deadline call");
    let body_text = String::from_utf8_lossy(&r.body).to_string();
    assert_eq!(r.status, 408, "DeadlineExceeded must map to 408; body: {body_text}");
    let j = json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    assert_eq!(j.req("finish_reason").unwrap().as_str().unwrap(), "deadline_exceeded");

    // burst past queue_depth (8): the overflow sheds with 429 and the
    // rejected bodies carry the typed reason
    let burst: Vec<_> = (0..24)
        .map(|i| {
            let addr = addr.clone();
            let body = completion_json(&prompt_tokens(5, 100 + i), 12, "");
            std::thread::spawn(move || {
                http_call(&addr, "POST", "/v1/completions", Some(&body)).expect("burst call")
            })
        })
        .collect();
    let mut codes = Vec::new();
    for h in burst {
        let r = h.join().expect("burst thread");
        if r.status == 429 {
            let j = json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
            assert_eq!(j.req("finish_reason").unwrap().as_str().unwrap(), "rejected");
            assert_eq!(j.req("token_count").unwrap().as_usize().unwrap(), 0);
        }
        codes.push(r.status);
    }
    assert!(codes.iter().all(|c| *c == 200 || *c == 429), "burst statuses: {codes:?}");
    assert!(codes.contains(&429), "a 24-deep burst over depth 8 must shed");

    stop.shutdown();
    server.join().expect("server thread").expect("clean shutdown");
}
