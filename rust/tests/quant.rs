//! PR 10 integration tests: int8 SVD factors end-to-end.
//!
//! * registry grammar: `?quant=int8&group=32` composes with any method,
//!   bad recipes fail naming the spec;
//! * `CompressionPlan` v2 carries the recipe across disk and resolves
//!   through `runtime::resolve_plan`;
//! * a quantized plan builds a serving engine whose factor weights are
//!   uploaded as packed int8, generates deterministically, and surfaces
//!   the recipe through `Engine::quant` / `GenStats` / the provenance
//!   line — the contract DESIGN.md §9 pins.

use std::sync::Mutex;

use ara_compress::compress::CompressionPlan;
use ara_compress::coordinator::Pipeline;
use ara_compress::model::{ModuleAlloc, WeightStore};
use ara_compress::quant::{quantized_factors, QuantScheme};

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl.scalecfg.alloc_samples = 16;
    pl.scalecfg.alloc_epochs = 2;
    pl.scalecfg.eval_batches = 2;
    pl.scalecfg.zs_items = 6;
    pl
}

/// Serialize the train-or-load step against the shared disk cache (same
/// contract as tests/integration.rs).
fn pretrained(pl: &Pipeline) -> WeightStore {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    pl.pretrained().expect("pretrain substrate")
}

#[test]
fn quant_params_compose_and_bad_recipes_name_the_spec() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();

    // `group` without `quant=int8` is rejected through the front door
    let err =
        pl.allocate_spec("uniform@0.8?group=32", &ws, &grams, &fm).unwrap_err().to_string();
    assert!(err.contains("group"), "{err}");

    let err = pl
        .allocate_spec("uniform@0.8?quant=fp4", &ws, &grams, &fm)
        .unwrap_err()
        .to_string();
    assert!(err.contains("fp4"), "{err}");

    // quant=none is the explicit f32 spelling
    let plan = pl.allocate_spec("uniform@0.8?quant=none", &ws, &grams, &fm).unwrap();
    assert_eq!(plan.quant(), None);
}

#[test]
fn ara_quant_spec_allocates_with_the_recipe() {
    // the acceptance spelling: `ara@0.8?quant=int8` just works, with the
    // default group of 32
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let plan = pl.allocate_spec("ara@0.8?quant=int8", &ws, &grams, &fm).unwrap();
    assert_eq!(plan.method, "ara");
    assert_eq!(plan.quant(), Some(QuantScheme { bits: 8, group: 32 }));
    assert!(plan.allocation.name.ends_with("-q8g32"), "{}", plan.allocation.name);
    assert!(plan.spec.contains("quant=int8"), "{}", plan.spec);
}

#[test]
fn quantized_plan_roundtrips_and_resolves_with_recipe() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let plan = pl.allocate_spec("uniform@0.8?quant=int8&group=16", &ws, &grams, &fm).unwrap();
    assert_eq!(plan.quant(), Some(QuantScheme { bits: 8, group: 16 }));

    // disk roundtrip keeps the recipe
    let tmp = std::env::temp_dir().join(format!("ara-quant-plan-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let path = tmp.join("plan.json");
    plan.save(&path).unwrap();
    let back = CompressionPlan::load(&path).unwrap();
    assert_eq!(plan, back);
    let _ = std::fs::remove_dir_all(&tmp);

    // resolve_plan through a scratch artifacts dir keeps the recipe too
    let tmp = std::env::temp_dir().join(format!("ara-quant-resolve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    let mut paths = pl.paths.clone();
    paths.artifacts = tmp.clone();
    let dir = tmp.join("allocations");
    std::fs::create_dir_all(&dir).unwrap();
    plan.save(&dir.join(format!("{}.{}.json", pl.cfg.name, plan.allocation.name))).unwrap();
    let resolved =
        ara_compress::runtime::resolve_plan(&pl.cfg, &paths, &plan.allocation.name).unwrap();
    assert_eq!(resolved.quant(), Some(QuantScheme { bits: 8, group: 16 }));
    assert_eq!(resolved.allocation, plan.allocation);
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn quantized_engine_serves_deterministically_and_reports_recipe() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let plan = pl.allocate_spec("uniform@0.8?quant=int8&group=32", &ws, &grams, &fm).unwrap();

    let engine = pl.engine_for_plan(&ws, &fm, &plan, 2).expect("quantized engine");
    assert_eq!(engine.quant(), Some(QuantScheme { bits: 8, group: 32 }));

    let prompts = vec![vec![0i32; pl.cfg.prefill_len], vec![5i32; pl.cfg.prefill_len]];
    let (a, stats) = engine.generate(&prompts, 8).unwrap();
    let (b, _) = engine.generate(&prompts, 8).unwrap();
    assert_eq!(a, b, "quantized greedy decode must be deterministic");
    assert_eq!(a[0].len(), 8);
    for toks in &a {
        for &tok in toks {
            assert!((tok as usize) < pl.cfg.vocab, "out-of-vocab token {tok}");
        }
    }
    assert_eq!(stats.tokens_generated, 2 * 8);
    assert_eq!(stats.quant, Some(QuantScheme { bits: 8, group: 32 }));
    let prov = stats.provenance.expect("plan-built engine carries provenance");
    assert!(prov.contains("int8/g32"), "{prov}");
}

#[test]
fn quantized_factors_measure_what_the_engine_serves() {
    // `quantized_factors` builds the f32 twin of the packed weights the
    // engine uploads: for every Rank(k) module, the first k columns/rows of
    // the factor matrices must equal dequant(quantize(truncate(k))) exactly
    // — this equivalence is what lets the ppl gate score served quality
    // through the ordinary masked eval path.
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let plan = pl.allocate_spec("uniform@0.8?quant=int8&group=32", &ws, &grams, &fm).unwrap();

    let fq = quantized_factors(&fm, &plan.allocation, 32);
    let mut checked = 0usize;
    for (name, alloc) in &plan.allocation.modules {
        let k = match alloc {
            ModuleAlloc::Rank(k) => *k,
            ModuleAlloc::Dense => continue,
        };
        let (u, v) = fm.factors[name].truncate(k);
        let qu = ara_compress::quant::PackedInt8::quantize(&u, 32).dequant();
        let qv = ara_compress::quant::PackedInt8::quantize(&v, 32).dequant();
        let (gu, gv) = fq.factors[name].truncate(k);
        assert_eq!(gu.data, qu.data, "{name}.u");
        assert_eq!(gv.data, qv.data, "{name}.v");
        // quantization must actually change something at int8 precision
        assert_ne!(gu.data, u.data, "{name}.u unchanged by quantize-dequant?");
        checked += 1;
    }
    assert!(checked > 0, "allocation had no low-rank modules to check");
}
