//! Continuous-batching scheduler tests: ragged-prompt parity with the
//! monolithic `Engine::generate` path, mid-flight admission and slot
//! reuse, and seeded-sampling determinism at the serve-loop level.
//! (Pure sampler edge cases live in `src/serving/sampler.rs` unit tests.)

use std::sync::Mutex;

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::model::WeightStore;
use ara_compress::serving::{Request, SamplingParams, Scheduler};
use ara_compress::svd::FactoredModel;

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    // tiny recipe: these tests check plumbing and invariants, not quality
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl
}

/// The pre-trained substrate is disk-cached and shared by every test
/// binary; serialize the train-or-load step so parallel tests don't race
/// the cache (same pattern as tests/integration.rs).
fn substrate(pl: &Pipeline) -> (WeightStore, FactoredModel) {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    let ws = pl.pretrained().expect("pretrain substrate");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    (ws, fm)
}

/// ≥ 2× batch-size ragged requests through one batch-2 engine: every
/// request's greedy output must match a standalone `Engine::generate` run
/// of the same prompt, despite mid-flight admission into reused slots.
#[test]
fn scheduler_matches_engine_generate_under_continuous_batching() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let p = pl.cfg.prefill_len; // 8 for micro-llama
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 11, 4096);

    // mixed prompt lengths (incl. full-length and near-empty) and mixed
    // generation lengths; the last request overruns the KV cache on purpose
    let lens = [3usize, 8, 5, 1, 7];
    let gens = [6usize, 3, 9, 5, pl.cfg.max_decode_seq];
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            prompt: stream[i * 17..i * 17 + lens[i]].to_vec(),
            gen_len: gens[i],
            params: SamplingParams::greedy(),
        })
        .collect();

    let mut sched = Scheduler::new(&engine);
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop");
    assert_eq!(done.len(), reqs.len());
    assert_eq!(sched.stats().completed, reqs.len());
    assert_eq!(sched.stats().admitted, reqs.len());
    done.sort_by_key(|c| c.id);

    // parity: each request alone through the monolithic greedy path (its
    // slot-1 neighbor is an arbitrary dummy — rows are independent)
    for (i, c) in done.iter().enumerate() {
        let prompts = vec![reqs[i].prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, reqs[i].gen_len).expect("generate");
        assert_eq!(c.tokens, toks[0], "request {i} diverged from Engine::generate");
        assert!(!c.tokens.is_empty());
        assert!(c.tokens.len() <= reqs[i].gen_len);
    }
    // the cache-guard request stopped early, exactly like generate
    assert!(done[4].tokens.len() < gens[4], "cache guard must bound generation");

    // 5 requests over 2 slots ⇒ both slots must have been reused, and
    // admission happened across several prefill rounds (mid-flight)
    let mut by_slot = [0usize; 2];
    for c in &done {
        by_slot[c.slot] += 1;
    }
    assert!(by_slot.iter().all(|&n| n >= 2), "slot reuse expected, got {by_slot:?}");
    assert!(sched.stats().prefills >= 2, "expected mid-flight admissions");
}

/// Sampled serving: the same seeds replay bit-identically across two
/// scheduler runs, and a nonzero temperature actually changes the output
/// relative to greedy for at least one request.
#[test]
fn seeded_sampling_is_deterministic_across_serve_loops() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 23, 2048);

    let reqs: Vec<Request> = (0..4)
        .map(|i| Request {
            prompt: stream[i * 13..i * 13 + 2 + i].to_vec(),
            gen_len: 8,
            params: SamplingParams {
                temperature: 2.0,
                top_k: 0,
                top_p: 0.95,
                seed: 1000 + i as u64,
            },
        })
        .collect();

    let run = |reqs: &[Request]| -> Vec<Vec<i32>> {
        let mut sched = Scheduler::new(&engine);
        for r in reqs {
            sched.submit(r.clone());
        }
        let mut done = sched.run_to_completion().expect("serve loop");
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };

    let a = run(&reqs);
    let b = run(&reqs);
    assert_eq!(a, b, "same seeds must replay the same streams");

    let greedy: Vec<Request> = reqs
        .iter()
        .map(|r| Request { params: SamplingParams::greedy(), ..r.clone() })
        .collect();
    let g = run(&greedy);
    assert_ne!(a, g, "temperature sampling should diverge from greedy somewhere");

    // all sampled tokens stay in-vocab
    for toks in &a {
        for &t in toks {
            assert!((t as usize) < pl.cfg.vocab, "token {t} out of vocab");
        }
    }
}

/// Admission while the batch is mid-decode: submit one long request, step a
/// few times, then submit more — the late arrivals must still match their
/// standalone generate runs (the splice into live caches is row-exact).
#[test]
fn late_submission_into_running_batch_keeps_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 31, 2048);

    let long = Request {
        prompt: stream[0..p].to_vec(),
        gen_len: 12,
        params: SamplingParams::greedy(),
    };
    let late_a = Request {
        prompt: stream[40..44].to_vec(),
        gen_len: 6,
        params: SamplingParams::greedy(),
    };
    let late_b = Request {
        prompt: stream[80..86].to_vec(),
        gen_len: 4,
        params: SamplingParams::greedy(),
    };

    let mut sched = Scheduler::new(&engine);
    sched.submit(long.clone());
    let mut done = Vec::new();
    for _ in 0..3 {
        done.extend(sched.step().expect("step"));
    }
    assert_eq!(sched.active(), 1, "long request still decoding");
    sched.submit(late_a.clone());
    sched.submit(late_b.clone());
    done.extend(sched.run_to_completion().expect("drain"));
    assert_eq!(done.len(), 3);
    done.sort_by_key(|c| c.id);

    for (c, r) in done.iter().zip([&long, &late_a, &late_b]) {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(c.tokens, toks[0], "late-admitted request diverged");
    }
}
