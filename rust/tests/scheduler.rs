//! Continuous-batching scheduler tests: ragged-prompt parity with the
//! monolithic `Engine::generate` path (which runs the contiguous per-slot
//! cache graph while the scheduler runs the block-paged pool graph — a
//! cross-implementation bitwise pin), mid-flight admission and slot reuse,
//! prefix sharing and pool accounting, preemption under pool exhaustion,
//! seeded-sampling determinism, and router error recovery.
//! (Pure sampler edge cases live in `src/serving/sampler.rs` unit tests.)

use std::sync::Mutex;

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::model::WeightStore;
use ara_compress::serving::{
    FinishReason, KvPoolCfg, Request, Router, SamplingParams, Scheduler, ServeRequest,
};
use ara_compress::svd::FactoredModel;

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    // tiny recipe: these tests check plumbing and invariants, not quality
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl
}

/// The pre-trained substrate is disk-cached and shared by every test
/// binary; serialize the train-or-load step so parallel tests don't race
/// the cache (same pattern as tests/integration.rs).
fn substrate(pl: &Pipeline) -> (WeightStore, FactoredModel) {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    let ws = pl.pretrained().expect("pretrain substrate");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    (ws, fm)
}

/// ≥ 2× batch-size ragged requests through one batch-2 engine: every
/// request's greedy output must match a standalone `Engine::generate` run
/// of the same prompt, despite mid-flight admission into reused slots.
#[test]
fn scheduler_matches_engine_generate_under_continuous_batching() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let p = pl.cfg.prefill_len; // 8 for micro-llama
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 11, 4096);

    // mixed prompt lengths (incl. full-length and near-empty) and mixed
    // generation lengths; the last request overruns the KV cache on purpose
    let lens = [3usize, 8, 5, 1, 7];
    let gens = [6usize, 3, 9, 5, pl.cfg.max_decode_seq];
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            prompt: stream[i * 17..i * 17 + lens[i]].to_vec(),
            gen_len: gens[i],
            params: SamplingParams::greedy(),
            ..Default::default()
        })
        .collect();

    let mut sched = Scheduler::new(&engine);
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop");
    assert_eq!(done.len(), reqs.len());
    assert_eq!(sched.stats().completed, reqs.len());
    assert_eq!(sched.stats().admitted, reqs.len());
    done.sort_by_key(|c| c.id);

    // parity: each request alone through the monolithic greedy path (its
    // slot-1 neighbor is an arbitrary dummy — rows are independent)
    for (i, c) in done.iter().enumerate() {
        let prompts = vec![reqs[i].prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, reqs[i].gen_len).expect("generate");
        assert_eq!(c.tokens, toks[0], "request {i} diverged from Engine::generate");
        assert!(!c.tokens.is_empty());
        assert!(c.tokens.len() <= reqs[i].gen_len);
    }
    // the cache-guard request stopped early, exactly like generate, and
    // reports Length (KV exhaustion surfaced, not silently swallowed)
    assert!(done[4].tokens.len() < gens[4], "cache guard must bound generation");
    assert_eq!(done[4].finish_reason, FinishReason::Length);
    for c in &done[..4] {
        assert_eq!(c.finish_reason, FinishReason::Stop, "request {} reason", c.id);
    }

    // 5 requests over 2 slots ⇒ both slots must have been reused, and
    // admission happened across several prefill rounds (mid-flight)
    let mut by_slot = [0usize; 2];
    for c in &done {
        by_slot[c.slot] += 1;
    }
    assert!(by_slot.iter().all(|&n| n >= 2), "slot reuse expected, got {by_slot:?}");
    assert!(sched.stats().prefills >= 2, "expected mid-flight admissions");
}

/// Sampled serving: the same seeds replay bit-identically across two
/// scheduler runs, and a nonzero temperature actually changes the output
/// relative to greedy for at least one request.
#[test]
fn seeded_sampling_is_deterministic_across_serve_loops() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 23, 2048);

    let reqs: Vec<Request> = (0..4)
        .map(|i| Request {
            prompt: stream[i * 13..i * 13 + 2 + i].to_vec(),
            gen_len: 8,
            params: SamplingParams {
                temperature: 2.0,
                top_k: 0,
                top_p: 0.95,
                seed: 1000 + i as u64,
            },
            ..Default::default()
        })
        .collect();

    let run = |reqs: &[Request]| -> Vec<Vec<i32>> {
        let mut sched = Scheduler::new(&engine);
        for r in reqs {
            sched.submit(r.clone());
        }
        let mut done = sched.run_to_completion().expect("serve loop");
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };

    let a = run(&reqs);
    let b = run(&reqs);
    assert_eq!(a, b, "same seeds must replay the same streams");

    let greedy: Vec<Request> = reqs
        .iter()
        .map(|r| Request { params: SamplingParams::greedy(), ..r.clone() })
        .collect();
    let g = run(&greedy);
    assert_ne!(a, g, "temperature sampling should diverge from greedy somewhere");

    // all sampled tokens stay in-vocab
    for toks in &a {
        for &t in toks {
            assert!((t as usize) < pl.cfg.vocab, "token {t} out of vocab");
        }
    }
}

/// Admission while the batch is mid-decode: submit one long request, step a
/// few times, then submit more — the late arrivals must still match their
/// standalone generate runs (the splice into live caches is row-exact).
#[test]
fn late_submission_into_running_batch_keeps_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 31, 2048);

    let long = Request {
        prompt: stream[0..p].to_vec(),
        gen_len: 12,
        params: SamplingParams::greedy(),
        ..Default::default()
    };
    let late_a = Request {
        prompt: stream[40..44].to_vec(),
        gen_len: 6,
        params: SamplingParams::greedy(),
        ..Default::default()
    };
    let late_b = Request {
        prompt: stream[80..86].to_vec(),
        gen_len: 4,
        params: SamplingParams::greedy(),
        ..Default::default()
    };

    let mut sched = Scheduler::new(&engine);
    sched.submit(long.clone());
    let mut done = Vec::new();
    for _ in 0..3 {
        done.extend(sched.step().expect("step"));
    }
    assert_eq!(sched.active(), 1, "long request still decoding");
    sched.submit(late_a.clone());
    sched.submit(late_b.clone());
    done.extend(sched.run_to_completion().expect("drain"));
    assert_eq!(done.len(), 3);
    done.sort_by_key(|c| c.id);

    for (c, r) in done.iter().zip([&long, &late_a, &late_b]) {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(c.tokens, toks[0], "late-admitted request diverged");
    }
}

/// Degenerate-config parity anchor: with `block_len = max_decode_seq` (one
/// block per sequence — the pre-paged contiguous layout, physically) and
/// prefix sharing disabled, the paged scheduler must produce bitwise the
/// same token streams as both the default-geometry paged run and the
/// contiguous `Engine::generate` reference, over the same mixed-length
/// trace as the main parity test.
#[test]
fn degenerate_block_config_matches_default_and_contiguous_paths() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 11, 4096);
    let lens = [3usize, 8, 5, 1, 7];
    let gens = [6usize, 3, 9, 5, 12];
    let reqs: Vec<Request> = (0..5)
        .map(|i| Request {
            prompt: stream[i * 17..i * 17 + lens[i]].to_vec(),
            gen_len: gens[i],
            params: SamplingParams::greedy(),
            ..Default::default()
        })
        .collect();

    let run = |engine: &ara_compress::serving::Engine| -> Vec<Vec<i32>> {
        let mut sched = Scheduler::new(engine);
        for r in &reqs {
            sched.submit(r.clone());
        }
        let mut done = sched.run_to_completion().expect("serve loop");
        done.sort_by_key(|c| c.id);
        done.into_iter().map(|c| c.tokens).collect()
    };

    // default geometry (env defaults: block = prefill window)
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let default_out = run(&engine);

    // degenerate geometry: one block spans the whole decode window
    let mut degen = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    degen
        .enable_paged(
            &pl.rt,
            KvPoolCfg {
                block_len: pl.cfg.max_decode_seq,
                num_blocks: 4,
                prefix_sharing: false,
            },
        )
        .expect("degenerate paged specialization");
    let degen_out = run(&degen);
    assert_eq!(degen_out, default_out, "block size must not change outputs");

    // contiguous reference, one request at a time
    for (i, r) in reqs.iter().enumerate() {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(degen_out[i], toks[0], "request {i} diverged from contiguous path");
    }
}

/// Prefix sharing: ≥ 4 requests with an identical (full prefill-window)
/// prompt — the prefill runs once, later admissions reuse the cached
/// chain + logits row (asserted via pool accounting), and every greedy
/// output still matches a standalone `Engine::generate`.
#[test]
fn shared_prompt_prefills_once_and_keeps_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let mut engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let p = pl.cfg.prefill_len;
    // pin the geometry (env-independent): block = the prefill window, so
    // the shared prompt fills exactly one full block
    engine
        .enable_paged(&pl.rt, KvPoolCfg { block_len: p, num_blocks: 16, prefix_sharing: true })
        .expect("paged specialization");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 47, 2048);
    let shared: Vec<i32> = stream[..p].to_vec(); // the whole prefill window

    let gens = [4usize, 5, 6, 7];
    let mut sched = Scheduler::new(&engine);
    sched.submit(Request {
        prompt: shared.clone(),
        gen_len: gens[0],
        params: SamplingParams::greedy(),
        ..Default::default()
    });
    // admit + register the first request's chain before the sharers arrive
    let mut done = sched.step().expect("first step");
    for &g in &gens[1..] {
        sched.submit(Request {
            prompt: shared.clone(),
            gen_len: g,
            params: SamplingParams::greedy(),
            ..Default::default()
        });
    }
    done.extend(sched.run_to_completion().expect("drain"));
    assert_eq!(done.len(), 4);
    done.sort_by_key(|c| c.id);

    // pool accounting: one prefill total, three full-prompt cache hits
    let stats = sched.stats();
    assert_eq!(stats.prefills, 1, "prefill must run once for the shared blocks");
    assert_eq!(stats.prefill_skipped, 3, "sharers must skip prefill");
    assert_eq!(stats.prefix_hits, 3);
    assert!(stats.prefix_hit_rate() > 0.7, "rate {}", stats.prefix_hit_rate());
    // the cached chain outlives the requests (held by the prefix map)
    assert!(sched.pool().cached_chains() >= 1);
    assert!(sched.pool().used_blocks() >= 1, "cache must keep the shared block");

    // parity: every sharer matches the standalone contiguous path
    for (c, &g) in done.iter().zip(&gens) {
        let prompts = vec![shared.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, g).expect("generate");
        assert_eq!(c.tokens, toks[0], "shared-prefix request {} diverged", c.id);
        assert_eq!(c.finish_reason, FinishReason::Stop);
    }
}

/// Pool exhaustion: with a pool too small for two full-length sequences,
/// the youngest request is preempted (requeued, restarted) instead of the
/// batch failing — and both requests still finish with parity outputs.
#[test]
fn pool_exhaustion_preempts_youngest_and_recovers() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len; // 8
    let mut engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    // 4 allocatable blocks of 8 slots: two 20-token generations (4 blocks
    // each) cannot coexist — the younger one must be preempted
    engine
        .enable_paged(&pl.rt, KvPoolCfg { block_len: p, num_blocks: 5, prefix_sharing: false })
        .expect("small pool");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 53, 2048);
    let reqs: Vec<Request> = (0..2)
        .map(|i| Request {
            prompt: stream[i * 31..i * 31 + p].to_vec(),
            gen_len: 20,
            params: SamplingParams::greedy(),
            ..Default::default()
        })
        .collect();

    let mut sched = Scheduler::new(&engine);
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop");
    assert_eq!(done.len(), 2);
    done.sort_by_key(|c| c.id);
    assert!(sched.stats().preemptions >= 1, "expected at least one preemption");
    assert!(sched.stats().pool_peak_util > 0.9, "pool should have run hot");
    for (c, r) in done.iter().zip(&reqs) {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(c.tokens, toks[0], "preempted request diverged after restart");
        assert_eq!(c.finish_reason, FinishReason::Stop);
    }
}

/// Router error recovery: a transient engine failure mid-trace is absorbed
/// by the resilience layer — the in-flight requests are re-queued and
/// retried (restart through prefill, original sampler seeds), so **every**
/// request completes `Stop` with parity outputs. The router keeps serving
/// afterwards. (Deeper fault coverage lives in `tests/chaos.rs`.)
#[test]
fn router_recovers_queued_requests_after_transient_engine_failure() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    // parity reference engine on this thread
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 67, 4096);
    let reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            prompt: stream[i * 19..i * 19 + 2 + i].to_vec(),
            gen_len: 6,
            params: SamplingParams::greedy(),
            ..Default::default()
        })
        .collect();

    // the worker engine rebuilds the (disk-cached) substrate on its own
    // thread and trips one injected decode fault a few steps in
    let router = Router::spawn(move || {
        let pl = pipeline();
        let (ws, fm) = substrate(&pl);
        let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("worker engine");
        engine.inject_decode_fault(3);
        engine
    });

    let receivers: Vec<_> = reqs
        .iter()
        .map(|r| {
            router
                .submit(ServeRequest {
                    prompt: r.prompt.clone(),
                    gen_len: r.gen_len,
                    params: r.params.clone(),
                    ..Default::default()
                })
                .expect("worker alive")
        })
        .collect();
    let mut retried = 0usize;
    for (rx, r) in receivers.into_iter().zip(&reqs) {
        let resp = rx.recv().expect("typed response, never a dropped channel");
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (toks, _) = engine.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(resp.tokens, toks[0], "recovered request diverged");
        assert_eq!(resp.finish_reason, FinishReason::Stop);
        retried += resp.retries as usize;
    }
    assert!(retried >= 1, "the in-flight requests must have been retried");

    // the router is still alive and serving after the recovery
    let rx = router
        .submit(ServeRequest {
            prompt: stream[500..504].to_vec(),
            gen_len: 3,
            params: SamplingParams::greedy(),
            ..Default::default()
        })
        .expect("worker alive");
    let resp = rx.recv().expect("router must keep serving after recovery");
    let prompts = vec![stream[500..504].to_vec(), vec![1i32; p]];
    let (toks, _) = engine.generate(&prompts, 3).expect("generate");
    assert_eq!(resp.tokens, toks[0]);
}
