//! Property-based tests over the coordinator invariants (routing, batching,
//! allocation state) — randomized sweeps with the in-crate PRNG (the
//! offline vendor set has no proptest; the generate→check loops below play
//! the same role, 100+ cases per property).

use ara_compress::ara::{binary_mask, rescale_to_target, Staircase};
use ara_compress::data::Rng;
use ara_compress::linalg::project_simplex;
use ara_compress::model::{alloc_params_for_dims, ModuleAlloc, ModuleDim};
use ara_compress::serving::DynamicBatcher;

fn random_dims(rng: &mut Rng, n: usize) -> Vec<ModuleDim> {
    (0..n)
        .map(|i| ModuleDim {
            name: format!("m{i}"),
            m: 8 + rng.below(120),
            n: 8 + rng.below(120),
        })
        .collect()
}

#[test]
fn prop_rescale_meets_budget_and_caps() {
    let mut rng = Rng::new(11);
    for case in 0..120 {
        let n_mods = 2 + rng.below(20);
        let dims = random_dims(&mut rng, n_mods);
        let ratios: Vec<f64> = dims.iter().map(|_| rng.f64() * 1.5).collect();
        let target = 0.1 + rng.f64() * 0.85;
        let alloc = rescale_to_target(&dims, &ratios, target, "t");
        let total: usize = dims.iter().map(|d| d.dense_params()).sum();
        let got = alloc_params_for_dims(&dims, &alloc) as f64 / total as f64;
        // within one rank unit of every module + dense-cap slack
        let slack: f64 =
            dims.iter().map(|d| (d.m + d.n) as f64).sum::<f64>() / total as f64;
        assert!(
            got <= 1.0 + 1e-9 && (got - target).abs() <= slack + 0.02,
            "case {case}: target {target:.3} got {got:.3} slack {slack:.3}"
        );
        for (d, _) in dims.iter().zip(&ratios) {
            match alloc.get(&d.name) {
                ModuleAlloc::Rank(k) => {
                    assert!(k >= 1 && k <= d.r_full());
                    // never store more than dense
                    assert!(d.factored_params(k) < d.dense_params());
                }
                ModuleAlloc::Dense => {}
            }
        }
    }
}

#[test]
fn prop_rescale_monotone_in_target() {
    let mut rng = Rng::new(12);
    for _ in 0..60 {
        let n_mods = 2 + rng.below(12);
        let dims = random_dims(&mut rng, n_mods);
        let ratios: Vec<f64> = dims.iter().map(|_| 0.2 + rng.f64()).collect();
        let lo = rescale_to_target(&dims, &ratios, 0.3, "lo");
        let hi = rescale_to_target(&dims, &ratios, 0.8, "hi");
        assert!(
            alloc_params_for_dims(&dims, &lo) <= alloc_params_for_dims(&dims, &hi),
            "params must grow with target"
        );
    }
}

#[test]
fn prop_staircase_mask_monotone_and_adjoint() {
    let mut rng = Rng::new(13);
    for _ in 0..150 {
        let d = 1 + rng.below(40);
        let r = 1 + rng.below(80);
        let st = Staircase::new(d, r);
        let mut alpha: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        project_simplex(&mut alpha);
        let p = st.prob_mask(&alpha);
        for i in 1..r {
            assert!(p[i - 1] >= p[i] - 1e-12);
        }
        assert!(p.iter().all(|&x| (-1e-12..=1.0 + 1e-9).contains(&x)));
        // adjoint identity <Mᵀg, α> = <g, Mα>
        let g: Vec<f64> = (0..r).map(|_| rng.normal()).collect();
        let lhs: f64 = st.chain_grad(&g).iter().zip(&alpha).map(|(a, b)| a * b).sum();
        let rhs: f64 = g.iter().zip(&p).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-9);
    }
}

#[test]
fn prop_binary_mask_param_consistency() {
    // the binary mask must store (≈) the expected parameter count of the
    // probabilistic mask: |k − Σp| ≤ 0.5
    let mut rng = Rng::new(14);
    for _ in 0..150 {
        let d = ModuleDim { name: "x".into(), m: 4 + rng.below(90), n: 4 + rng.below(90) };
        let r = d.r_full();
        let mut p: Vec<f64> = (0..r).map(|_| rng.f64()).collect();
        p.sort_by(|a, b| b.partial_cmp(a).unwrap()); // monotone like αM
        let st = binary_mask(&d, &p);
        let sum: f64 = p.iter().sum();
        if st.k > 1 && st.k < r {
            assert!((st.k as f64 - sum).abs() <= 0.5 + 1e-9, "k={} Σp={sum}", st.k);
        }
        // dense flag consistent with Eq. 3 ratio
        assert_eq!(st.dense, st.ratio >= 1.0);
    }
}

#[test]
fn prop_batcher_covers_all_requests_exactly_once() {
    let mut rng = Rng::new(15);
    for _ in 0..200 {
        let mut sizes: Vec<usize> = vec![1, 2, 4, 8, 16];
        sizes.truncate(1 + rng.below(5));
        let b = DynamicBatcher::new(sizes.clone());
        let pending = rng.below(70);
        let plans = b.plan(pending);
        let mut seen = vec![false; pending];
        for plan in &plans {
            assert!(sizes.contains(&plan.batch), "unknown batch size");
            assert!(plan.requests.len() <= plan.batch);
            for &r in &plan.requests {
                assert!(!seen[r], "request {r} scheduled twice");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "requests dropped: {pending} pending");
    }
}

#[test]
fn prop_simplex_projection_is_projection() {
    let mut rng = Rng::new(16);
    for _ in 0..200 {
        let n = 1 + rng.below(50);
        let mut v: Vec<f64> = (0..n).map(|_| rng.normal() * 3.0).collect();
        project_simplex(&mut v);
        let s: f64 = v.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(v.iter().all(|&x| x >= 0.0));
        let w = v.clone();
        project_simplex(&mut v);
        for (a, b) in v.iter().zip(&w) {
            assert!((a - b).abs() < 1e-9, "idempotence");
        }
    }
}

#[test]
fn prop_corpus_batches_never_cross_windows() {
    let mut rng = Rng::new(17);
    for _ in 0..50 {
        let len = 200 + rng.below(2000);
        let stream: Vec<i32> = (0..len as i32).collect();
        let batch = 1 + rng.below(6);
        let seq = 2 + rng.below(40);
        for (toks, tgts) in ara_compress::data::batches(&stream, batch, seq) {
            for s in 0..batch {
                for t in 0..seq {
                    assert_eq!(tgts.data[s * seq + t], toks.data[s * seq + t] + 1);
                }
            }
        }
    }
}
