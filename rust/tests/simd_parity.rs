//! SIMD-tier determinism contract (DESIGN.md §performance): every tier
//! runnable on this CPU must produce **bitwise-identical** results to the
//! scalar reference for both matmul micro-kernel paths (packed axpy and
//! small-m dot), on awkward non-lane-multiple shapes, zero-sized edges,
//! NaN/subnormal inputs, and any thread count.

use ara_compress::kernels::{available_tiers, bmm_f32_tier, matmul_f32_tier, matmul_q8_tier, SimdTier};
use ara_compress::quant::PackedInt8;
use ara_compress::tensor::Tensor;

/// Deterministic pseudo-random fill in [-0.5, 0.5).
fn fill(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
    (0..n)
        .map(|_| {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            ((s >> 33) as f32 / (1u64 << 31) as f32) - 0.5
        })
        .collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: elem {i} differs (tier {x:e} vs scalar {y:e})"
        );
    }
}

fn mm(tier: SimdTier, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, ta: bool, tb: bool, nt: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    matmul_f32_tier(tier, a, b, m, k, n, ta, tb, &mut out, nt);
    out
}

#[test]
fn every_tier_matches_scalar_on_non_lane_multiple_shapes() {
    // k values straddle the 8-lane chunking (k % 8 ∈ {1, 3, 5, 7}); m
    // values cover both the small-m dot fast path (m < 8 with tb) and the
    // packed path; n values are not multiples of any vector width.
    let shapes = [(1, 1, 1), (1, 131, 9), (3, 7, 5), (5, 137, 33), (7, 61, 1), (12, 45, 19)];
    for tier in available_tiers() {
        for &(m, k, n) in &shapes {
            for &ta in &[false, true] {
                for &tb in &[false, true] {
                    let a = fill(m * k, 21 + m as u64);
                    let b = fill(k * n, 22 + n as u64);
                    let want = mm(SimdTier::Scalar, &a, &b, m, k, n, ta, tb, 1);
                    let got = mm(tier, &a, &b, m, k, n, ta, tb, 1);
                    assert_bits_eq(
                        &got,
                        &want,
                        &format!("{} {m}x{k}x{n} ta={ta} tb={tb}", tier.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn zero_sized_shapes_are_noops_on_every_tier() {
    for tier in available_tiers() {
        for &(m, k, n) in &[(0usize, 5usize, 3usize), (4, 0, 3), (4, 5, 0)] {
            let a = fill(m * k, 31);
            let b = fill(k * n, 32);
            let out = mm(tier, &a, &b, m, k, n, false, false, 1);
            // k == 0 contracts an empty axis: the output must stay zero
            assert!(out.iter().all(|&v| v == 0.0), "{}: {m}x{k}x{n}", tier.name());
        }
    }
}

#[test]
fn nan_inf_and_subnormal_inputs_propagate_identically() {
    let (m, k, n) = (6, 37, 11);
    let mut a = fill(m * k, 41);
    let mut b = fill(k * n, 42);
    a[3] = f32::NAN;
    a[k + 5] = f32::INFINITY;
    a[2 * k] = 0.0; // exercises the zero-rank skip against a NaN row of b
    b[4 * n + 2] = f32::NAN;
    b[7 * n + 1] = f32::NEG_INFINITY;
    // subnormals: smallest positive and a mid-range denormal
    a[5] = f32::from_bits(1);
    b[9 * n + 3] = f32::from_bits(0x0000_4000);
    for tier in available_tiers() {
        for &tb in &[false, true] {
            let want = mm(SimdTier::Scalar, &a, &b, m, k, n, false, tb, 1);
            let got = mm(tier, &a, &b, m, k, n, false, tb, 1);
            assert_bits_eq(&got, &want, &format!("{} nan/subnormal tb={tb}", tier.name()));
        }
    }
}

#[test]
fn thread_count_is_invariant_within_each_tier() {
    let (m, k, n) = (9, 130, 37);
    let a = fill(m * k, 51);
    let b = fill(k * n, 52);
    for tier in available_tiers() {
        let base = mm(tier, &a, &b, m, k, n, false, true, 1);
        for nt in [2, 3, 4, 8] {
            let got = mm(tier, &a, &b, m, k, n, false, true, nt);
            assert_bits_eq(&got, &base, &format!("{} nt={nt}", tier.name()));
        }
    }
}

/// Dequant-then-f32 reference for the quantized matmul: y = x · dequant(w)ᵀ
/// computed with the f32 kernel contract. The int8 kernel dequantizes
/// per-element with the identical lane schedule, so every tier must match
/// this reference **bitwise** — the quantized path buys bytes, not drift.
fn mm_q8_reference(x: &[f32], w: &PackedInt8, m: usize) -> Vec<f32> {
    let (n, k) = (w.shape[0], w.shape[1]);
    let dq = w.dequant();
    let mut out = vec![0.0f32; m * n];
    matmul_f32_tier(SimdTier::Scalar, x, &dq.data, m, k, n, false, true, &mut out, 1);
    out
}

fn pack(n_rows: usize, k: usize, group: usize, seed: u64) -> PackedInt8 {
    let w = Tensor::from_vec(&[n_rows, k], fill(n_rows * k, seed));
    PackedInt8::quantize(&w, group)
}

#[test]
fn q8_matmul_matches_dequant_reference_bitwise_on_every_tier() {
    // k values straddle both the 8-lane chunking AND the scale-group
    // boundaries: k=70/group=32 leaves a 6-wide ragged last group; group=5
    // forces group crossings *inside* every 8-lane chunk; k=23 < group=32
    // exercises the single-partial-group row.
    for &(m, k, n, group) in
        &[(1usize, 70usize, 9usize, 32usize), (3, 23, 5, 32), (5, 64, 13, 16), (4, 37, 7, 5)]
    {
        let x = fill(m * k, 71 + k as u64);
        let w = pack(n, k, group, 72 + n as u64);
        let want = mm_q8_reference(&x, &w, m);
        for tier in available_tiers() {
            let mut got = vec![0.0f32; m * n];
            matmul_q8_tier(tier, &x, &w, m, &mut got, 1);
            assert_bits_eq(
                &got,
                &want,
                &format!("q8 {} {m}x{k}x{n} g{group}", tier.name()),
            );
        }
    }
}

#[test]
fn q8_matmul_is_thread_count_invariant_within_each_tier() {
    let (m, k, n, group) = (9, 130, 37, 32);
    let x = fill(m * k, 81);
    let w = pack(n, k, group, 82);
    for tier in available_tiers() {
        let mut base = vec![0.0f32; m * n];
        matmul_q8_tier(tier, &x, &w, m, &mut base, 1);
        for nt in [2, 3, 4, 8] {
            let mut got = vec![0.0f32; m * n];
            matmul_q8_tier(tier, &x, &w, m, &mut got, nt);
            assert_bits_eq(&got, &base, &format!("q8 {} nt={nt}", tier.name()));
        }
    }
}

#[test]
fn bmm_tiers_match_scalar_including_the_decode_dot_path() {
    // m = 1 with tb is exactly the decode attention-score shape, which
    // takes the dot fast path inside each batch slice
    let (bs, m, k, n) = (5, 1, 24, 13);
    let a = fill(bs * m * k, 61);
    let b = fill(bs * n * k, 62);
    for tier in available_tiers() {
        for nt in [1, 4] {
            let mut want = vec![0.0f32; bs * m * n];
            bmm_f32_tier(SimdTier::Scalar, &a, &b, bs, m, k, n, false, true, &mut want, 1);
            let mut got = vec![0.0f32; bs * m * n];
            bmm_f32_tier(tier, &a, &b, bs, m, k, n, false, true, &mut got, nt);
            assert_bits_eq(&got, &want, &format!("bmm {} nt={nt}", tier.name()));
        }
    }
}
