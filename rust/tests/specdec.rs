//! Self-speculative decoding tests (DESIGN.md §8): the load-bearing
//! contract is that with greedy sampling the accepted token stream is
//! **bitwise identical** to the plain serving path — `Engine::generate`
//! through the contiguous graph — for every prompt, every draft plan, and
//! every draft length `k`. Speculation is a throughput optimization, never
//! a sampling change. Also covered: mixed spec/plain batches, mid-stream
//! rejection, cache-overrun prompts, and interaction with the PR-7 fault
//! injection (target- and draft-side).

use std::sync::Mutex;

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::model::WeightStore;
use ara_compress::serving::{
    Engine, FinishReason, Request, SamplingParams, SchedStats, Scheduler, SpecDec,
};
use ara_compress::svd::FactoredModel;

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl
}

/// Serialize the train-or-load step against the shared disk cache (same
/// pattern as tests/scheduler.rs).
fn substrate(pl: &Pipeline) -> (WeightStore, FactoredModel) {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    let ws = pl.pretrained().expect("pretrain substrate");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    (ws, fm)
}

/// Target engine with the verify window armed for draft length `k`, plus
/// a draft engine of `draft_alloc` wrapped in a [`SpecDec`].
fn spec_pair(
    pl: &Pipeline,
    ws: &WeightStore,
    fm: &FactoredModel,
    draft_alloc: &str,
    batch: usize,
    k: usize,
) -> (Engine, SpecDec) {
    let mut target = pl.engine(ws, fm, "uniform-80", batch).expect("target engine");
    target.enable_verify(&pl.rt, k + 1).expect("verify specialization");
    let draft = pl.engine(ws, fm, draft_alloc, batch).expect("draft engine");
    let sd = SpecDec::new(draft, draft_alloc, k).expect("spec dec");
    (target, sd)
}

/// Run `reqs` through a speculative scheduler; returns per-request token
/// streams (id order) and the final stats.
fn run_spec(engine: &Engine, sd: SpecDec, reqs: &[Request]) -> (Vec<Vec<i32>>, SchedStats) {
    let mut sched = Scheduler::new(engine);
    sched.set_spec_dec(Some(sd)).expect("install spec dec");
    for r in reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop");
    done.sort_by_key(|c| c.id);
    let stats = sched.stats().clone();
    (done.into_iter().map(|c| c.tokens).collect(), stats)
}

/// The tentpole pin: across draft lengths k ∈ {1, 2, 4, 8} and a heavy
/// draft plan, every greedy stream is bitwise identical to the standalone
/// contiguous `Engine::generate` run — mid-stream rejections and all.
#[test]
fn spec_streams_bitwise_match_plain_greedy_across_k() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 11, 4096);
    let lens = [3usize, 8, 5, 1];
    let gens = [9usize, 6, 12, 7];

    for &k in &[1usize, 2, 4, 8] {
        let (target, sd) = spec_pair(&pl, &ws, &fm, "uniform-40", 2, k);
        let reqs: Vec<Request> = (0..4)
            .map(|i| Request {
                prompt: stream[i * 17..i * 17 + lens[i]].to_vec(),
                gen_len: gens[i],
                params: SamplingParams::greedy(),
                draft_spec: Some("uniform-40".into()),
                ..Default::default()
            })
            .collect();
        let (toks, stats) = run_spec(&target, sd, &reqs);
        for (i, r) in reqs.iter().enumerate() {
            let prompts = vec![r.prompt.clone(), vec![1i32; p]];
            let (plain, _) = target.generate(&prompts, r.gen_len).expect("generate");
            assert_eq!(toks[i], plain[0], "k={k} request {i} diverged from plain greedy");
        }
        assert!(stats.verify_passes > 0, "k={k}: no verify pass ran");
        assert!(stats.draft_tokens > 0, "k={k}: no draft tokens proposed");
        assert!(stats.draft_accepted <= stats.draft_tokens);
        let apv = stats.accepted_per_verify();
        assert!(
            (0.0..=k as f64).contains(&apv),
            "k={k}: accepted_per_verify {apv} out of [0, {k}]"
        );
    }
}

/// A draft built from the *same* allocation as the target proposes the
/// target's own argmax — acceptance should be near-total, exercising the
/// full-acceptance catch-up feed; parity must still hold exactly.
#[test]
fn identical_draft_plan_accepts_and_keeps_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 29, 2048);
    let (target, sd) = spec_pair(&pl, &ws, &fm, "uniform-80", 2, 3);
    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            prompt: stream[i * 23..i * 23 + 2 + i].to_vec(),
            gen_len: 10,
            params: SamplingParams::greedy(),
            draft_spec: Some("uniform-80".into()),
            ..Default::default()
        })
        .collect();
    let (toks, stats) = run_spec(&target, sd, &reqs);
    for (i, r) in reqs.iter().enumerate() {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (plain, _) = target.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(toks[i], plain[0], "self-draft request {i} diverged");
    }
    // the draft *is* the target, so proposals can only be rejected at
    // finish/window boundaries — acceptance must dominate
    assert!(stats.draft_accepted > 0, "identical draft must accept tokens");
    assert!(
        stats.draft_accept_rate() > 0.5,
        "identical draft accept rate {} suspiciously low",
        stats.draft_accept_rate()
    );
    // speculation must beat one-token-per-step: each verify pass emits at
    // least one token per drafted slot, and first tokens come from prefill
    assert!(
        stats.verify_passes < stats.tokens_generated,
        "accounting: {} verify passes for {} generated tokens",
        stats.verify_passes,
        stats.tokens_generated
    );
}

/// Spec and plain requests share one batch: opted-in slots run the verify
/// window while opted-out (no draft named / sampled) slots ride window
/// position 0 — everyone keeps parity, and the sampled request replays its
/// seeded stream exactly.
#[test]
fn mixed_spec_and_plain_requests_coexist_in_one_batch() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 37, 2048);
    let (target, sd) = spec_pair(&pl, &ws, &fm, "uniform-40", 2, 4);

    let mk = |i: usize, draft: Option<&str>, params: SamplingParams| Request {
        prompt: stream[i * 19..i * 19 + 3 + i].to_vec(),
        gen_len: 8,
        params,
        draft_spec: draft.map(str::to_string),
        ..Default::default()
    };
    let reqs = vec![
        mk(0, Some("uniform-40"), SamplingParams::greedy()),
        mk(1, None, SamplingParams::greedy()),
        mk(2, Some("uniform-40"), SamplingParams::greedy()),
        // sampled → spec-ineligible even though it names the draft
        mk(3, Some("uniform-40"), SamplingParams { temperature: 1.5, top_k: 0, top_p: 1.0, seed: 7 }),
    ];
    let (toks, stats) = run_spec(&target, sd, &reqs);
    assert!(stats.verify_passes > 0, "spec slots must have run verify rounds");

    // greedy requests (spec or plain) match the contiguous reference
    for i in [0usize, 1, 2] {
        let prompts = vec![reqs[i].prompt.clone(), vec![1i32; p]];
        let (plain, _) = target.generate(&prompts, reqs[i].gen_len).expect("generate");
        assert_eq!(toks[i], plain[0], "mixed-batch request {i} diverged");
    }
    // the sampled request replays bit-identically on a plain scheduler
    let mut sched = Scheduler::new(&target);
    sched.submit(reqs[3].clone());
    let done = sched.run_to_completion().expect("plain serve loop");
    assert_eq!(toks[3], done[0].tokens, "sampled request not spec-invariant");
}

/// Cache-overrun prompts: a full-window prompt generating to the KV limit
/// finishes `Length` at exactly the plain path's cut, with the draft
/// retiring at the window-end guard instead of overrunning.
#[test]
fn cache_overrun_prompts_stop_at_length_with_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 41, 2048);
    let (target, sd) = spec_pair(&pl, &ws, &fm, "uniform-40", 2, 4);
    let reqs: Vec<Request> = (0..2)
        .map(|i| Request {
            prompt: stream[i * 31..i * 31 + p].to_vec(),
            gen_len: pl.cfg.max_decode_seq,
            params: SamplingParams::greedy(),
            draft_spec: Some("uniform-40".into()),
            ..Default::default()
        })
        .collect();

    let mut sched = Scheduler::new(&target);
    sched.set_spec_dec(Some(sd)).expect("install spec dec");
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop");
    done.sort_by_key(|c| c.id);
    for (c, r) in done.iter().zip(&reqs) {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (plain, _) = target.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(c.tokens, plain[0], "overrun request diverged");
        assert_eq!(c.finish_reason, FinishReason::Length, "KV exhaustion must surface");
        assert!(c.tokens.len() < pl.cfg.max_decode_seq);
    }
}

/// PR-7 fault interaction, target side: an injected decode fault fires
/// inside the verify pass; the resilience layer requeues and retries, and
/// the regenerated stream is still bitwise identical.
#[test]
fn target_fault_during_verify_retries_to_identical_stream() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 43, 2048);
    let (target, sd) = spec_pair(&pl, &ws, &fm, "uniform-40", 2, 2);
    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            prompt: stream[i * 13..i * 13 + 2 + i].to_vec(),
            gen_len: 8,
            params: SamplingParams::greedy(),
            draft_spec: Some("uniform-40".into()),
            ..Default::default()
        })
        .collect();

    target.inject_decode_fault(2);
    let (toks, stats) = run_spec(&target, sd, &reqs);
    assert_eq!(stats.decode_faults, 1, "the injected fault must have fired");
    assert!(stats.retries >= 1, "in-flight requests must have been retried");
    for (i, r) in reqs.iter().enumerate() {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (plain, _) = target.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(toks[i], plain[0], "post-fault request {i} diverged");
    }
}

/// PR-7 fault interaction, draft side: a fault in the *draft* engine must
/// never surface to the request — the draft poisons itself, the batch
/// falls back to plain decode, and streams stay identical with zero
/// target-side faults recorded.
#[test]
fn draft_fault_falls_back_to_plain_with_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 47, 2048);
    let mut target = pl.engine(&ws, &fm, "uniform-80", 2).expect("target engine");
    target.enable_verify(&pl.rt, 3).expect("verify specialization");
    let draft = pl.engine(&ws, &fm, "uniform-40", 2).expect("draft engine");
    draft.inject_decode_fault(1);
    let sd = SpecDec::new(draft, "uniform-40", 2).expect("spec dec");

    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            prompt: stream[i * 11..i * 11 + 2 + i].to_vec(),
            gen_len: 7,
            params: SamplingParams::greedy(),
            draft_spec: Some("uniform-40".into()),
            ..Default::default()
        })
        .collect();
    let (toks, stats) = run_spec(&target, sd, &reqs);
    assert_eq!(stats.decode_faults, 0, "a draft fault must not count as a target fault");
    assert_eq!(stats.retries, 0, "a draft fault must not requeue requests");
    for (i, r) in reqs.iter().enumerate() {
        let prompts = vec![r.prompt.clone(), vec![1i32; p]];
        let (plain, _) = target.generate(&prompts, r.gen_len).expect("generate");
        assert_eq!(toks[i], plain[0], "draft-fault request {i} diverged");
    }
}

/// Installation contract: the scheduler refuses a decoder whose `k` does
/// not match the armed verify window, and a target without the verify
/// specialization at all.
#[test]
fn set_spec_dec_validates_window_and_batch() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    // no verify armed → refused
    let bare = pl.engine(&ws, &fm, "uniform-80", 2).expect("target engine");
    let draft = pl.engine(&ws, &fm, "uniform-40", 2).expect("draft engine");
    let sd = SpecDec::new(draft, "uniform-40", 2).expect("spec dec");
    let mut sched = Scheduler::new(&bare);
    assert!(sched.set_spec_dec(Some(sd)).is_err(), "must require enable_verify");

    // window mismatch (armed for k=4, decoder built for k=2) → refused
    let mut target = pl.engine(&ws, &fm, "uniform-80", 2).expect("target engine");
    target.enable_verify(&pl.rt, 5).expect("verify specialization");
    let draft = pl.engine(&ws, &fm, "uniform-40", 2).expect("draft engine");
    let sd = SpecDec::new(draft, "uniform-40", 2).expect("spec dec");
    let mut sched = Scheduler::new(&target);
    assert!(sched.set_spec_dec(Some(sd)).is_err(), "must pin window = k + 1");

    // clearing is always fine
    assert!(sched.set_spec_dec(None).is_ok());
}
