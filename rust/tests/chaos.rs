//! Seeded chaos-harness tests for the serving resilience layer
//! (DESIGN.md §5): fault-isolated recovery with deterministic retry
//! (plan-injected and real engine errors, prefill and decode), quarantine
//! past the retry budget, cancellation and step-budget deadlines (queued
//! and mid-decode), pool-pressure spikes, and bounded router admission.
//! The load-bearing invariant throughout: every request that is not
//! failed/cancelled/expired finishes **bitwise identical** to a fault-free
//! `Engine::generate` run — retries restart through prefill (or the
//! prefix cache) with their original sampler seeds.

use std::sync::{mpsc, Mutex};

use ara_compress::coordinator::Pipeline;
use ara_compress::data::{corpus_spec, generate_tokens};
use ara_compress::model::WeightStore;
use ara_compress::serving::{
    CancelToken, FaultPlan, FinishReason, KvPoolCfg, Request, Router, RouterCfg, SamplingParams,
    SchedCfg, Scheduler, ServeRequest, NO_SLOT,
};
use ara_compress::svd::FactoredModel;

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    // tiny recipe: these tests check resilience plumbing, not quality
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl
}

/// Serialize the train-or-load step against the shared disk cache (same
/// pattern as tests/scheduler.rs).
fn substrate(pl: &Pipeline) -> (WeightStore, FactoredModel) {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    let ws = pl.pretrained().expect("pretrain substrate");
    let grams = pl.grams(&ws).expect("calibrate");
    let fm = pl.factored(&ws, &grams).expect("factorize");
    (ws, fm)
}

/// Fault-free reference: the request alone through the monolithic greedy
/// path (its slot-1 neighbor is an arbitrary dummy — rows are independent).
fn reference(engine: &ara_compress::serving::Engine, prompt: &[i32], gen_len: usize) -> Vec<i32> {
    let p = engine.config().prefill_len;
    let prompts = vec![prompt.to_vec(), vec![1i32; p]];
    let (toks, _) = engine.generate(&prompts, gen_len).expect("reference generate");
    toks[0].clone()
}

fn greedy(prompt: Vec<i32>, gen_len: usize) -> Request {
    Request { prompt, gen_len, params: SamplingParams::greedy(), ..Default::default() }
}

/// Plan-injected decode faults fire before the pool buffers are consumed:
/// recovery releases blocks per-slot (no pool reset), the in-flight
/// requests are re-queued and retried, and every completion is bitwise
/// identical to a fault-free run.
#[test]
fn plan_decode_faults_retry_with_bitwise_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 71, 2048);
    let reqs: Vec<Request> =
        (0..4).map(|i| greedy(stream[i * 23..i * 23 + 2 + i].to_vec(), 5 + i)).collect();

    let mut sched = Scheduler::new_with(&engine, SchedCfg::default());
    sched.set_fault_plan(Some(FaultPlan::parse("decode@2?count=2").expect("plan")));
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop under faults");
    assert_eq!(done.len(), reqs.len());
    done.sort_by_key(|c| c.id);

    let stats = sched.stats();
    assert_eq!(stats.decode_faults, 2, "both planned faults must fire");
    assert!(stats.retries >= 2, "in-flight requests must have been retried");
    assert_eq!(stats.quarantined, 0, "budget of 3 absorbs 2 faults");
    assert_eq!(stats.pool_resets, 0, "plan faults recover without a pool reset");
    let retried: u32 = done.iter().map(|c| c.retries).sum();
    assert!(retried >= 2, "completions must carry their retry counts");
    for (c, r) in done.iter().zip(&reqs) {
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert_eq!(
            c.tokens,
            reference(&engine, &r.prompt, r.gen_len),
            "request {} diverged after fault recovery",
            c.id
        );
    }
}

/// A real engine error inside `decode_step_paged` consumes the in-flight
/// pool buffers: recovery rebuilds the pool (prefix cache included) and
/// restarts every in-flight request — still bitwise identical.
#[test]
fn engine_error_resets_pool_and_recovers_bitwise() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 73, 2048);
    let reqs: Vec<Request> =
        (0..3).map(|i| greedy(stream[i * 29..i * 29 + 3 + i].to_vec(), 7)).collect();
    // references first: the injected fault counts *every* decode step on
    // this engine, including the reference generates
    let refs: Vec<Vec<i32>> =
        reqs.iter().map(|r| reference(&engine, &r.prompt, r.gen_len)).collect();

    engine.inject_decode_fault(3);
    let mut sched = Scheduler::new_with(&engine, SchedCfg::default());
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop under engine error");
    assert_eq!(done.len(), reqs.len());
    done.sort_by_key(|c| c.id);

    let stats = sched.stats();
    assert_eq!(stats.decode_faults, 1);
    assert_eq!(stats.pool_resets, 1, "lost buffers must rebuild the pool");
    assert!(stats.retries >= 1);
    assert!(stats.last_fault.as_deref().is_some_and(|m| m.contains("injected")));
    for (c, r) in done.iter().zip(refs.iter()) {
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert_eq!(c.tokens, *r, "request {} diverged after pool reset", c.id);
    }
}

/// A prefill fault is contained to the admissions that needed that
/// prefill: the active request keeps decoding the same step, the casualty
/// is re-queued and retried, and both finish with parity outputs.
#[test]
fn prefill_fault_is_isolated_to_admissions() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 79, 2048);
    let a = greedy(stream[0..5].to_vec(), 10);
    let b = greedy(stream[50..54].to_vec(), 6);

    let mut sched = Scheduler::new_with(&engine, SchedCfg::default());
    sched.set_fault_plan(Some(FaultPlan::parse("prefill@1").expect("plan")));
    sched.submit(a.clone());
    let mut done = sched.step().expect("step 0: admit the active request");
    assert_eq!(sched.active(), 1);
    sched.submit(b.clone());
    done.extend(sched.run_to_completion().expect("drain"));
    assert_eq!(done.len(), 2);
    done.sort_by_key(|c| c.id);

    let stats = sched.stats();
    assert_eq!(stats.prefill_faults, 1, "the planned prefill fault must fire");
    assert_eq!(stats.decode_faults, 0, "the active slot must not be touched");
    assert_eq!(done[0].retries, 0, "the active request never saw the fault");
    assert_eq!(done[1].retries, 1, "the admission casualty retried once");
    for (c, r) in done.iter().zip([&a, &b]) {
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert_eq!(c.tokens, reference(&engine, &r.prompt, r.gen_len));
    }
}

/// Past the retry budget a request is quarantined with a typed
/// `Failed { retries }` (partial tokens attached) — and the scheduler
/// keeps serving new requests cleanly afterwards.
#[test]
fn quarantine_after_retry_budget_is_typed_and_contained() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 83, 2048);
    let doomed = greedy(stream[0..6].to_vec(), 6);

    let mut sched = Scheduler::new_with(&engine, SchedCfg { retry_limit: 1 });
    sched.set_fault_plan(Some(FaultPlan::parse("decode@1?count=2").expect("plan")));
    sched.submit(doomed.clone());
    let done = sched.run_to_completion().expect("serve loop");
    assert_eq!(done.len(), 1);
    let c = &done[0];
    assert_eq!(c.finish_reason, FinishReason::Failed { retries: 1 });
    assert_eq!(c.retries, 1);
    assert!(!c.tokens.is_empty(), "partial tokens travel with the quarantine");
    let full = reference(&engine, &doomed.prompt, doomed.gen_len);
    assert_eq!(c.tokens, full[..c.tokens.len()], "partial tokens stay on the parity stream");
    let stats = sched.stats();
    assert_eq!(stats.quarantined, 1);
    assert_eq!(stats.retries, 1);
    assert_eq!(stats.decode_faults, 2);
    assert!(stats.last_fault.is_some());

    // the plan is exhausted: a follow-up request serves cleanly
    let after = greedy(stream[100..104].to_vec(), 4);
    sched.submit(after.clone());
    let done = sched.run_to_completion().expect("post-quarantine serve");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish_reason, FinishReason::Stop);
    assert_eq!(done[0].tokens, reference(&engine, &after.prompt, after.gen_len));
}

/// Cancelling a mid-decode request completes it `Cancelled` at the next
/// step boundary with its partial tokens, and frees its slot and KV
/// blocks immediately.
#[test]
fn cancellation_mid_decode_frees_blocks() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len;
    let mut engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    // sharing off so zero retained blocks is the exact post-release state
    engine
        .enable_paged(&pl.rt, KvPoolCfg { block_len: p, num_blocks: 8, prefix_sharing: false })
        .expect("paged specialization");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 89, 2048);
    let token = CancelToken::new();
    let req = Request {
        prompt: stream[0..p].to_vec(),
        gen_len: 20,
        params: SamplingParams::greedy(),
        cancel: Some(token.clone()),
        ..Default::default()
    };

    let mut sched = Scheduler::new_with(&engine, SchedCfg::default());
    sched.submit(req.clone());
    for _ in 0..3 {
        assert!(sched.step().expect("step").is_empty(), "still decoding");
    }
    assert!(sched.pool().used_blocks() > 0, "the active request holds blocks");
    token.cancel();
    let done = sched.step().expect("cancellation sweep");
    assert_eq!(done.len(), 1);
    let c = &done[0];
    assert_eq!(c.finish_reason, FinishReason::Cancelled);
    assert!(!c.tokens.is_empty() && c.tokens.len() < req.gen_len, "partial cut");
    let full = reference(&engine, &req.prompt, req.gen_len);
    assert_eq!(c.tokens, full[..c.tokens.len()], "partial tokens stay on the parity stream");
    assert_eq!(sched.pool().used_blocks(), 0, "cancellation must free the KV blocks");
    assert_eq!(sched.stats().cancelled, 1);
    assert!(sched.is_idle());
}

/// Step-budget deadlines: a queued request that never wins a slot expires
/// with `NO_SLOT` and no tokens; an admitted request expires mid-decode
/// with its partial tokens and frees its blocks.
#[test]
fn deadline_expires_queued_and_active_requests() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 97, 2048);

    // both slots busy for ~13 steps; the third request expires queued
    let mut sched = Scheduler::new_with(&engine, SchedCfg::default());
    let long_a = greedy(stream[0..6].to_vec(), 15);
    let long_b = greedy(stream[40..45].to_vec(), 15);
    sched.submit(long_a.clone());
    sched.submit(long_b.clone());
    let starved_id = sched.submit(Request {
        prompt: stream[80..84].to_vec(),
        gen_len: 4,
        params: SamplingParams::greedy(),
        deadline_steps: Some(3),
        ..Default::default()
    });
    let done = sched.run_to_completion().expect("serve loop");
    assert_eq!(done.len(), 3);
    let starved = done.iter().find(|c| c.id == starved_id).expect("expired completion");
    assert_eq!(starved.finish_reason, FinishReason::DeadlineExceeded);
    assert_eq!(starved.slot, NO_SLOT, "never admitted");
    assert!(starved.tokens.is_empty());
    for c in done.iter().filter(|c| c.id != starved_id) {
        assert_eq!(c.finish_reason, FinishReason::Stop, "unexpired requests unaffected");
    }
    assert_eq!(sched.stats().deadline_expired, 1);

    // an admitted request expires mid-decode with partial tokens
    let cut = Request {
        prompt: stream[120..126].to_vec(),
        gen_len: 20,
        params: SamplingParams::greedy(),
        deadline_steps: Some(4),
        ..Default::default()
    };
    sched.submit(cut.clone());
    let done = sched.run_to_completion().expect("serve loop");
    assert_eq!(done.len(), 1);
    let c = &done[0];
    assert_eq!(c.finish_reason, FinishReason::DeadlineExceeded);
    assert_ne!(c.slot, NO_SLOT, "was admitted");
    assert!(!c.tokens.is_empty() && c.tokens.len() < cut.gen_len, "partial cut");
    let full = reference(&engine, &cut.prompt, cut.gen_len);
    assert_eq!(c.tokens, full[..c.tokens.len()]);
    assert_eq!(sched.stats().deadline_expired, 2);
}

/// A pool-pressure spike (chaos `spike` event) squeezes a pool that would
/// otherwise fit both requests: the youngest is preempted, restarts after
/// the hold releases, and both finish `Stop` with parity outputs.
#[test]
fn spike_pressure_preempts_and_recovers_with_parity() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let p = pl.cfg.prefill_len; // 8 for micro-llama
    let mut engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    // 8 allocatable blocks: both requests need 2 each — no pressure until
    // the spike grabs the remaining free blocks
    engine
        .enable_paged(&pl.rt, KvPoolCfg { block_len: p, num_blocks: 9, prefix_sharing: false })
        .expect("paged specialization");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 101, 2048);
    let reqs =
        [greedy(stream[0..4].to_vec(), 12), greedy(stream[60..64].to_vec(), 12)];

    let mut sched = Scheduler::new_with(&engine, SchedCfg::default());
    sched.set_fault_plan(Some(FaultPlan::parse("spike@2?blocks=6&hold=4").expect("plan")));
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("serve loop under spike");
    assert_eq!(done.len(), 2);
    done.sort_by_key(|c| c.id);
    assert!(sched.stats().preemptions >= 1, "the spike must force a preemption");
    assert_eq!(sched.stats().quarantined, 0, "pressure is not a fault");
    for (c, r) in done.iter().zip(&reqs) {
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert_eq!(c.tokens, reference(&engine, &r.prompt, r.gen_len));
    }
    assert_eq!(sched.pool().used_blocks(), 0, "spike holds must be released");
}

/// Bounded admission: past `queue_depth` in-flight requests the router
/// sheds with an immediate typed `Rejected`; admitted requests still serve
/// with parity once the worker comes up.
#[test]
fn router_sheds_past_queue_depth_with_typed_rejection() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("parity engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 103, 2048);
    let reqs: Vec<Request> =
        (0..4).map(|i| greedy(stream[i * 33..i * 33 + 3 + i].to_vec(), 5)).collect();

    // hold the worker at the gate until all submits landed, so the depth
    // counter deterministically sheds requests 3 and 4
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let cfg = RouterCfg { queue_depth: 2, ..RouterCfg::default() };
    let router = Router::spawn_with(cfg, move || {
        gate_rx.recv().ok();
        let pl = pipeline();
        let (ws, fm) = substrate(&pl);
        pl.engine(&ws, &fm, "uniform-80", 2).expect("worker engine")
    });
    let receivers: Vec<_> = reqs
        .iter()
        .map(|r| {
            router
                .submit(ServeRequest {
                    prompt: r.prompt.clone(),
                    gen_len: r.gen_len,
                    params: r.params.clone(),
                    ..Default::default()
                })
                .expect("worker alive")
        })
        .collect();
    assert_eq!(router.shed(), 2, "requests past the depth must shed");
    assert_eq!(router.in_flight(), 2);
    gate_tx.send(()).expect("gate");

    for (i, (rx, r)) in receivers.into_iter().zip(&reqs).enumerate() {
        let resp = rx.recv().expect("typed response, never a dropped channel");
        if i < 2 {
            assert_eq!(resp.finish_reason, FinishReason::Stop, "admitted request {i}");
            assert_eq!(resp.tokens, reference(&engine, &r.prompt, r.gen_len));
        } else {
            assert_eq!(resp.finish_reason, FinishReason::Rejected, "shed request {i}");
            assert!(resp.tokens.is_empty());
            assert_eq!(resp.retries, 0);
            assert!(resp.error.is_none());
            assert!(!resp.finish_reason.is_natural());
        }
    }
    assert_eq!(router.in_flight(), 0, "depth returns to zero after answers");
}

/// Soak: a seeded Bernoulli fault plan (`rate@R`) over the whole trace —
/// with a roomy retry budget every request still finishes `Stop`, bitwise
/// identical to the fault-free references, and the loop terminates.
#[test]
fn seeded_rate_plan_soak_keeps_every_stream_bitwise() {
    let pl = pipeline();
    let (ws, fm) = substrate(&pl);
    let engine = pl.engine(&ws, &fm, "uniform-80", 2).expect("engine");
    let stream = generate_tokens(pl.cfg.vocab, corpus_spec("synwiki"), 107, 2048);
    let reqs: Vec<Request> =
        (0..3).map(|i| greedy(stream[i * 41..i * 41 + 2 + 2 * i].to_vec(), 6)).collect();

    let plan = FaultPlan::parse("rate@0.3?seed=5&until=40").expect("plan");
    assert!(plan.remaining() > 0, "rate 0.3 over 40 steps must schedule faults");
    let mut sched = Scheduler::new_with(&engine, SchedCfg { retry_limit: 64 });
    sched.set_fault_plan(Some(plan));
    for r in &reqs {
        sched.submit(r.clone());
    }
    let mut done = sched.run_to_completion().expect("soak serve loop");
    assert_eq!(done.len(), reqs.len());
    done.sort_by_key(|c| c.id);
    assert!(sched.stats().decode_faults >= 1, "the soak must actually inject faults");
    assert_eq!(sched.stats().quarantined, 0);
    for (c, r) in done.iter().zip(&reqs) {
        assert_eq!(c.finish_reason, FinishReason::Stop);
        assert_eq!(c.tokens, reference(&engine, &r.prompt, r.gen_len), "soak divergence");
    }
}
