//! Integration tests over the full pipeline — pretrain → calibrate →
//! factorize → allocate → evaluate → serve — running on the default
//! pure-Rust interpreter backend, so they pass on a clean checkout with no
//! XLA toolchain and no exported artifacts. (Set ARA_BACKEND=pjrt with
//! `--features pjrt` and `make artifacts` to drive the same tests through
//! PJRT.) They exercise the same code paths as the bench harnesses at the
//! smallest possible scale.

use std::sync::Mutex;

use ara_compress::coordinator::Pipeline;
use ara_compress::model::{alloc_ratio, module_dims, Allocation, ModuleAlloc, WeightStore};
use ara_compress::svd::alloc_masks;

/// Computed uniform allocation via the compress registry (tests never
/// call `baselines::*_alloc` free functions directly — PR 5 cut-over).
fn uniform(pl: &Pipeline, pct: usize) -> Allocation {
    ara_compress::compress::computed_alloc(&pl.cfg, &format!("uniform-{pct}"))
        .expect("computed name")
        .expect("uniform alloc")
}

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    // tiny recipe: these tests check plumbing and invariants, not quality
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl.scalecfg.alloc_samples = 16;
    pl.scalecfg.alloc_epochs = 2;
    pl.scalecfg.eval_batches = 2;
    pl.scalecfg.zs_items = 6;
    pl
}

/// The pre-trained substrate is disk-cached and shared by every test;
/// serialize the train-or-load step so parallel tests don't race the cache.
fn pretrained(pl: &Pipeline) -> WeightStore {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    pl.pretrained().expect("pretrain substrate")
}

#[test]
fn pretrain_reduces_loss() {
    let pl = pipeline();
    // fresh 30-step run (no cache): loss must drop from ~ln(vocab)
    let pc = ara_compress::training::PretrainConfig { steps: 30, ..Default::default() };
    let (_ws, report) = ara_compress::training::pretrain(&pl.cfg, &pl.rt, &pc).unwrap();
    assert!(report.initial_loss > report.final_loss, "{report:?}");
    assert!(report.initial_loss > 4.0, "init should be near ln(256)≈5.5");
}

#[test]
fn factored_full_mask_matches_dense_ppl() {
    // the repo's core numeric invariant, through the whole runtime stack:
    // all-ones masks over full-rank whitened factors == dense model
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();

    let mut dense_alloc = Allocation::new("dense");
    for d in module_dims(&pl.cfg) {
        dense_alloc.set(&d.name, ModuleAlloc::Dense);
    }
    let masks = alloc_masks(&pl.cfg, &dense_alloc);
    let ppl_f =
        ara_compress::eval::perplexity_masked(&pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 2)
            .unwrap();
    let ppl_d = ara_compress::eval::perplexity_dense(&pl.cfg, &pl.rt, &ws, "synwiki", 2).unwrap();
    let rel = (ppl_f.ppl - ppl_d.ppl).abs() / ppl_d.ppl;
    assert!(rel < 0.03, "factored@full-rank PPL {} vs dense {}", ppl_f.ppl, ppl_d.ppl);
}

#[test]
fn truncation_monotone_in_ratio() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let mut last = 0.0;
    for pct in [90, 50, 15] {
        let alloc = uniform(&pl, pct);
        let masks = alloc_masks(&pl.cfg, &alloc);
        let ppl =
            ara_compress::eval::perplexity_masked(&pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 2)
                .unwrap()
                .ppl;
        assert!(ppl >= last * 0.98, "ppl must not improve much as ratio shrinks");
        last = ppl;
    }
}

#[test]
fn every_method_hits_its_budget() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    for id in ["uniform", "dlp", "farms", "ars", "dobi", "ara", "ara-nolg"] {
        let plan = pl.allocate_spec(&format!("{id}@0.5"), &ws, &grams, &fm).unwrap();
        let got = alloc_ratio(&pl.cfg, &plan.allocation);
        assert!((got - 0.5).abs() < 0.12, "{id}: achieved {got} for target 0.5");
        assert!(
            (plan.achieved - got).abs() < 1e-12,
            "{id}: plan records achieved {} but ratio is {got}",
            plan.achieved
        );
        for (name, a) in &plan.allocation.modules {
            if let ModuleAlloc::Rank(k) = a {
                assert!(*k >= 1, "{name}: zero rank");
            }
        }
    }
}

#[test]
fn zero_shot_dense_beats_chance() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let zs = ara_compress::eval::zero_shot_suite(
        &pl.cfg,
        &pl.rt,
        &ara_compress::eval::Scorer::Dense { ws: &ws },
        20,
        42,
    )
    .unwrap();
    // chance over the suite ≈ 29% (mix of 2- and 4-way); a trained model
    // must clear it decisively
    assert!(zs.average > 40.0, "zero-shot avg {:.1} too close to chance", zs.average);
}

#[test]
fn serving_engine_generates_and_is_deterministic() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    // the same uniform-80 allocation the backend resolves for the artifact
    let alloc = uniform(&pl, 80);
    let engine =
        ara_compress::serving::Engine::new(&pl.cfg, &pl.rt, &ws, &fm, &alloc, "uniform-80", 2)
            .unwrap();
    let prompts = vec![vec![0i32; pl.cfg.prefill_len], vec![5i32; pl.cfg.prefill_len]];
    let (a, stats) = engine.generate(&prompts, 8).unwrap();
    let (b, _) = engine.generate(&prompts, 8).unwrap();
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert_eq!(a[0].len(), 8);
    assert!(stats.tok_per_s() > 0.0);

    // distinct prompts should not collapse to identical continuations of
    // each other under a trained model... but even if they do, the engine
    // must report coherent stats
    assert_eq!(stats.tokens_generated, 2 * 8);
}

#[test]
fn serving_dense_equals_scored_logits_path() {
    // decode over the dense allocation must generate in-vocab tokens and
    // respect the cache-length guard
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let mut alloc = Allocation::new("dense");
    for d in module_dims(&pl.cfg) {
        alloc.set(&d.name, ModuleAlloc::Dense);
    }
    let engine =
        ara_compress::serving::Engine::new(&pl.cfg, &pl.rt, &ws, &fm, &alloc, "dense", 1).unwrap();
    let prompts = vec![vec![1i32; pl.cfg.prefill_len]];
    let gen_len = pl.cfg.max_decode_seq; // longer than the cache allows
    let (toks, stats) = engine.generate(&prompts, gen_len).unwrap();
    assert!(!toks[0].is_empty());
    assert!(toks[0].len() <= gen_len);
    for &t in &toks[0] {
        assert!((t as usize) < pl.cfg.vocab, "token {t} out of vocab");
    }
    assert!(stats.steps < gen_len, "cache guard must stop the decode loop");
}

#[test]
fn lora_merge_preserves_or_improves_ppl() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let alloc = uniform(&pl, 40);
    let masks = alloc_masks(&pl.cfg, &alloc);
    let before =
        ara_compress::eval::perplexity_masked(&pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 2)
            .unwrap()
            .ppl;
    let lc = ara_compress::lora::LoraConfig { steps: 10, ..Default::default() };
    let (fm2, masks2) =
        ara_compress::lora::lora_finetune_and_merge(&pl.cfg, &pl.rt, &ws, &fm, &masks, &grams, &lc)
            .unwrap();
    let after =
        ara_compress::eval::perplexity_masked(&pl.cfg, &pl.rt, &ws, &fm2, &masks2, "synwiki", 2)
            .unwrap()
            .ppl;
    assert!(after <= before * 1.05, "LoRA should not hurt: {before} → {after}");
}

#[test]
fn qwen_family_graphs_run_end_to_end() {
    // GQA + QK-norm coverage: the qwen preset must pretrain a few steps
    // through the same backend
    let pl = Pipeline::new("miniqwen-s").unwrap();
    let pc = ara_compress::training::PretrainConfig { steps: 10, ..Default::default() };
    let (ws, report) = ara_compress::training::pretrain(&pl.cfg, &pl.rt, &pc).unwrap();
    assert!(report.final_loss.is_finite());
    let ppl = ara_compress::eval::perplexity_dense(&pl.cfg, &pl.rt, &ws, "synwiki", 1).unwrap();
    assert!(ppl.ppl.is_finite() && ppl.ppl > 1.0);
}
