//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` (micro-llama) to have run; each test skips
//! gracefully when artifacts are absent so `cargo test` stays green in a
//! fresh checkout. They run the same code paths as the bench harnesses at
//! the smallest possible scale.

use ara_compress::config::Paths;
use ara_compress::coordinator::{MethodKind, Pipeline};
use ara_compress::model::{alloc_ratio, module_dims, Allocation, ModuleAlloc};
use ara_compress::svd::alloc_masks;

fn pipeline() -> Option<Pipeline> {
    let paths = Paths::discover().ok()?;
    if !paths.artifact_dir("micro-llama").join("train_step.hlo.txt").exists() {
        eprintln!("skipping integration test: run `make artifacts` first");
        return None;
    }
    let mut pl = Pipeline::new("micro-llama").ok()?;
    // tiny recipe: these tests check plumbing, not quality
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1200);
    pl.scalecfg.calib_batches = 2;
    pl.scalecfg.alloc_samples = 16;
    pl.scalecfg.alloc_epochs = 2;
    pl.scalecfg.eval_batches = 2;
    pl.scalecfg.zs_items = 6;
    Some(pl)
}

#[test]
fn pretrain_reduces_loss() {
    let Some(pl) = pipeline() else { return };
    // fresh 30-step run (no cache): loss must drop from ~ln(vocab)
    let pc = ara_compress::training::PretrainConfig {
        steps: 30,
        ..Default::default()
    };
    let (_ws, report) = ara_compress::training::pretrain(&pl.cfg, &pl.rt, &pc).unwrap();
    assert!(report.initial_loss > report.final_loss, "{report:?}");
    assert!(report.initial_loss > 4.0, "init should be near ln(256)≈5.5");
}

#[test]
fn factored_full_mask_matches_dense_ppl() {
    // the repo's core numeric invariant, now through the real runtime:
    // all-ones masks over full-rank factors == dense model (up to f32)
    let Some(pl) = pipeline() else { return };
    let ws = pl.pretrained().unwrap();
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();

    let mut dense_alloc = Allocation::new("dense");
    for d in module_dims(&pl.cfg) {
        dense_alloc.set(&d.name, ModuleAlloc::Dense);
    }
    let masks = alloc_masks(&pl.cfg, &dense_alloc);
    let ppl_f = ara_compress::eval::perplexity_masked(
        &pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 2,
    )
    .unwrap();
    let ppl_d =
        ara_compress::eval::perplexity_dense(&pl.cfg, &pl.rt, &ws, "synwiki", 2).unwrap();
    let rel = (ppl_f.ppl - ppl_d.ppl).abs() / ppl_d.ppl;
    assert!(rel < 0.03, "factored@full-rank PPL {} vs dense {}", ppl_f.ppl, ppl_d.ppl);
}

#[test]
fn truncation_monotone_in_ratio() {
    let Some(pl) = pipeline() else { return };
    let ws = pl.pretrained().unwrap();
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let mut last = 0.0;
    for ratio in [0.9, 0.5, 0.15] {
        let alloc = ara_compress::baselines::uniform_alloc(&pl.cfg, ratio);
        let masks = alloc_masks(&pl.cfg, &alloc);
        let ppl = ara_compress::eval::perplexity_masked(
            &pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 2,
        )
        .unwrap()
        .ppl;
        assert!(ppl >= last * 0.98, "ppl must not improve much as ratio shrinks");
        last = ppl;
    }
}

#[test]
fn every_method_hits_its_budget() {
    let Some(pl) = pipeline() else { return };
    let ws = pl.pretrained().unwrap();
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    for m in [
        MethodKind::Uniform,
        MethodKind::Dlp,
        MethodKind::Farms,
        MethodKind::Ars,
        MethodKind::Dobi,
        MethodKind::Ara,
        MethodKind::AraNoGuidance,
    ] {
        let alloc = pl.allocate(m, 0.5, &ws, &grams, &fm).unwrap();
        let got = alloc_ratio(&pl.cfg, &alloc);
        assert!(
            (got - 0.5).abs() < 0.12,
            "{}: achieved {got} for target 0.5",
            m.name()
        );
        for (name, a) in &alloc.modules {
            if let ModuleAlloc::Rank(k) = a {
                assert!(*k >= 1, "{name}: zero rank");
            }
        }
    }
}

#[test]
fn zero_shot_dense_beats_chance() {
    let Some(pl) = pipeline() else { return };
    let ws = pl.pretrained().unwrap();
    let zs = ara_compress::eval::zero_shot_suite(
        &pl.cfg,
        &pl.rt,
        &ara_compress::eval::Scorer::Dense { ws: &ws },
        20,
        42,
    )
    .unwrap();
    // chance over the suite ≈ 29% (mix of 2- and 4-way); a trained model
    // must clear it decisively
    assert!(zs.average > 40.0, "zero-shot avg {:.1} too close to chance", zs.average);
}

#[test]
fn serving_engine_generates_and_is_deterministic() {
    let Some(pl) = pipeline() else { return };
    if !pl.paths.artifact_dir("micro-llama").join("decode_uniform-80_b2.hlo.txt").exists() {
        return;
    }
    let ws = pl.pretrained().unwrap();
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let alloc = Allocation::load(
        &pl.paths.artifacts.join("allocations/micro-llama.uniform-80.json"),
    )
    .unwrap();
    let engine = ara_compress::serving::Engine::new(
        &pl.cfg, &pl.rt, &ws, &fm, &alloc, "uniform-80", 2,
    )
    .unwrap();
    let prompts = vec![vec![0i32; pl.cfg.prefill_len], vec![5i32; pl.cfg.prefill_len]];
    let (a, stats) = engine.generate(&prompts, 8).unwrap();
    let (b, _) = engine.generate(&prompts, 8).unwrap();
    assert_eq!(a, b, "greedy decode must be deterministic");
    assert_eq!(a[0].len(), 8);
    assert!(stats.tok_per_s() > 0.0);
}

#[test]
fn lora_merge_preserves_or_improves_ppl() {
    let Some(pl) = pipeline() else { return };
    let ws = pl.pretrained().unwrap();
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    let alloc = ara_compress::baselines::uniform_alloc(&pl.cfg, 0.4);
    let masks = alloc_masks(&pl.cfg, &alloc);
    let before = ara_compress::eval::perplexity_masked(
        &pl.cfg, &pl.rt, &ws, &fm, &masks, "synwiki", 2,
    )
    .unwrap()
    .ppl;
    let lc = ara_compress::lora::LoraConfig { steps: 10, ..Default::default() };
    let (fm2, masks2) =
        ara_compress::lora::lora_finetune_and_merge(&pl.cfg, &pl.rt, &ws, &fm, &masks, &grams, &lc)
            .unwrap();
    let after = ara_compress::eval::perplexity_masked(
        &pl.cfg, &pl.rt, &ws, &fm2, &masks2, "synwiki", 2,
    )
    .unwrap()
    .ppl;
    assert!(after <= before * 1.05, "LoRA should not hurt: {before} → {after}");
}
