//! PR 5 cut-over tests: the unified compression-method API.
//!
//! * registry errors name the offending spec (`nosuch@0.8`,
//!   `ara@0.8?bogus=1`);
//! * `CompressionPlan` JSON round-trips, and `runtime::resolve_alloc`
//!   accepts both plan files and legacy bare-`Allocation` files;
//! * **parity pins**: for every method in the Table 1/2 set (plus the
//!   ara-nolg ablation), the registry path produces a bitwise-identical
//!   `Allocation` to the pre-refactor direct-call path on the micro
//!   preset — the contract that lets the deprecated shims be deleted
//!   next release;
//! * a freshly written plan round-trips through the Python mirror
//!   (`python/compile/plans.py`), pinning the cross-language schema.

use std::sync::Mutex;

use ara_compress::ara::{train_ara, AraConfig, MaskGradRunner};
use ara_compress::compress::{CompressionPlan, PlanScale, PLAN_SCHEMA_VERSION};
use ara_compress::coordinator::Pipeline;
use ara_compress::model::{Allocation, ModuleAlloc, WeightStore};
use ara_compress::Result;

fn pipeline() -> Pipeline {
    let mut pl = Pipeline::new("micro-llama").expect("pipeline (cpu backend needs no artifacts)");
    pl.scalecfg.pretrain_steps = std::env::var("ARA_PRETRAIN_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    pl.scalecfg.calib_batches = 2;
    pl.scalecfg.alloc_samples = 16;
    pl.scalecfg.alloc_epochs = 2;
    pl.scalecfg.eval_batches = 2;
    pl.scalecfg.zs_items = 6;
    pl
}

/// Serialize the train-or-load step against the shared disk cache (same
/// contract as tests/integration.rs).
fn pretrained(pl: &Pipeline) -> WeightStore {
    static LOCK: Mutex<()> = Mutex::new(());
    let _guard = LOCK.lock().unwrap();
    pl.pretrained().expect("pretrain substrate")
}

#[test]
fn unknown_method_and_param_errors_name_the_spec() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();

    let err = pl.allocate_spec("nosuch@0.8", &ws, &grams, &fm).unwrap_err().to_string();
    assert!(err.contains("nosuch@0.8"), "must name the spec: {err}");
    assert!(err.contains("uniform"), "must list known methods: {err}");

    let err = pl.allocate_spec("ara@0.8?bogus=1", &ws, &grams, &fm).unwrap_err().to_string();
    assert!(err.contains("ara@0.8?bogus=1"), "must name the spec: {err}");
    assert!(err.contains("bogus"), "must name the parameter: {err}");

    // a spec without a target is an error at the pipeline front door
    let err = pl.allocate_spec("uniform", &ws, &grams, &fm).unwrap_err().to_string();
    assert!(err.contains("uniform"), "{err}");
    assert!(err.contains("target"), "{err}");
}

/// The pre-refactor `Pipeline::allocate` construction, reproduced verbatim
/// (method free functions + inline constants: DLP tail 0.15, FARMS 0.3,
/// runner data seeds 3/4/5, Dobi 2× epochs). This is the ONLY place
/// outside `compress/` still touching the `*_alloc` free functions — it
/// exists to pin the registry bitwise-identical to the old path before
/// the deprecated shims are deleted.
fn pre_refactor_alloc(
    pl: &Pipeline,
    id: &str,
    target: f64,
    ws: &WeightStore,
    grams: &std::collections::BTreeMap<String, ara_compress::linalg::Mat>,
    fm: &ara_compress::svd::FactoredModel,
) -> Result<Allocation> {
    use ara_compress::baselines as b;
    let sc = &pl.scalecfg;
    match id {
        "uniform" => Ok(b::uniform_alloc(&pl.cfg, target)),
        "dlp" => Ok(b::dlp_alloc(&pl.cfg, ws, grams, target, 0.15)),
        "farms" => Ok(b::farms_alloc(&pl.cfg, fm, target, 0.3)),
        "strs" => {
            let runner =
                MaskGradRunner::new(&pl.cfg, &pl.rt, ws, fm, "sync4", sc.alloc_samples, 3)?;
            b::strs_alloc(&pl.cfg, &runner, fm, target, &b::StrsConfig::default())
        }
        "ars" => {
            let runner =
                MaskGradRunner::new(&pl.cfg, &pl.rt, ws, fm, "sync4", sc.alloc_samples, 4)?;
            let ac = b::ArsConfig { target, epochs: sc.alloc_epochs, ..Default::default() };
            b::ars_alloc(&pl.cfg, &runner, &ac)
        }
        "dobi" => {
            let runner =
                MaskGradRunner::new(&pl.cfg, &pl.rt, ws, fm, "sync4", sc.alloc_samples, 5)?;
            let dc = b::DobiConfig { target, epochs: sc.alloc_epochs * 2, ..Default::default() };
            b::dobi_alloc(&pl.cfg, &runner, &dc)
        }
        "ara" | "ara-nolg" => {
            let ac = AraConfig {
                target,
                epochs: sc.alloc_epochs,
                samples: sc.alloc_samples,
                use_guidance: id == "ara",
                ..Default::default()
            };
            let (alloc, _) = train_ara(&pl.cfg, &pl.rt, ws, fm, &ac)?;
            Ok(alloc)
        }
        other => Err(ara_compress::anyhow!("no pre-refactor recipe for {other}")),
    }
}

#[test]
fn registry_path_is_bitwise_identical_to_pre_refactor_path() {
    let pl = pipeline();
    let ws = pretrained(&pl);
    let grams = pl.grams(&ws).unwrap();
    let fm = pl.factored(&ws, &grams).unwrap();
    // ALL_METHODS (Table 1/2 grid) plus the Table 5 ablation
    for id in ["uniform", "dlp", "farms", "strs", "ars", "dobi", "ara", "ara-nolg"] {
        let old = pre_refactor_alloc(&pl, id, 0.5, &ws, &grams, &fm).expect("pre-refactor path");
        let plan = pl
            .allocate_spec(&format!("{id}@0.5"), &ws, &grams, &fm)
            .expect("registry path");
        assert_eq!(
            old, plan.allocation,
            "{id}: registry allocation diverged from the pre-refactor path"
        );
    }
}

#[test]
fn resolve_alloc_accepts_plans_and_legacy_allocation_files() {
    let pl = pipeline();
    // point artifact resolution at a scratch dir; configs stay real
    let tmp = std::env::temp_dir().join(format!("ara-registry-test-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    let mut paths = pl.paths.clone();
    paths.artifacts = tmp.clone();
    let dir = tmp.join("allocations");
    std::fs::create_dir_all(&dir).unwrap();

    // legacy bare-Allocation file
    let legacy = ara_compress::compress::computed_alloc(&pl.cfg, "uniform-70")
        .unwrap()
        .unwrap();
    let mut legacy_named = legacy.clone();
    legacy_named.name = "legacyfile".to_string();
    legacy_named.save(&dir.join(format!("{}.legacyfile.json", pl.cfg.name))).unwrap();
    let resolved =
        ara_compress::runtime::resolve_alloc(&pl.cfg, &paths, "legacyfile").unwrap();
    assert_eq!(resolved, legacy_named);

    // versioned plan file resolves to its wrapped allocation, with
    // provenance surfaced through resolve_plan
    let plan = CompressionPlan {
        schema_version: PLAN_SCHEMA_VERSION,
        spec: "uniform@0.7".to_string(),
        method: "uniform".to_string(),
        label: "Uniform".to_string(),
        target: 0.7,
        achieved: 0.69,
        seed: None,
        scale: PlanScale { alloc_samples: 16, alloc_epochs: 2 },
        wall_ms: 3.0,
        allocation: legacy.clone(),
    };
    plan.save(&dir.join(format!("{}.planfile.json", pl.cfg.name))).unwrap();
    let p = ara_compress::runtime::resolve_plan(&pl.cfg, &paths, "planfile").unwrap();
    assert!(p.provenanced());
    assert_eq!(p.spec, "uniform@0.7");
    assert_eq!(p.allocation, legacy);
    assert_eq!(
        ara_compress::runtime::resolve_alloc(&pl.cfg, &paths, "planfile").unwrap(),
        legacy
    );

    // unknown names still fail, naming both lookup locations
    let err = ara_compress::runtime::resolve_alloc(&pl.cfg, &paths, "missing-alloc")
        .unwrap_err()
        .to_string();
    assert!(err.contains("missing-alloc"), "{err}");

    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn plan_roundtrips_through_python_mirror() {
    let pl = pipeline();
    let mut alloc = Allocation::new("ara-80");
    alloc.set("layers.0.attn.wq", ModuleAlloc::Rank(5));
    alloc.set("layers.0.attn.wv", ModuleAlloc::Dense);
    let plan = CompressionPlan {
        schema_version: PLAN_SCHEMA_VERSION,
        spec: "ara@0.8?epochs=2".to_string(),
        method: "ara".to_string(),
        label: "ARA".to_string(),
        target: 0.8,
        achieved: 0.7931,
        seed: Some(7),
        scale: PlanScale { alloc_samples: 16, alloc_epochs: 2 },
        wall_ms: 12.5,
        allocation: alloc,
    };
    let tmp = std::env::temp_dir().join(format!("ara-plan-py-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let inp = tmp.join("plan.json");
    let out = tmp.join("plan.roundtrip.json");
    plan.save(&inp).unwrap();

    let script = pl
        .paths
        .configs
        .parent()
        .expect("repo root")
        .join("python/compile/plans.py");
    let status = match std::process::Command::new("python3")
        .arg(&script)
        .arg("--roundtrip")
        .arg(&inp)
        .arg(&out)
        .status()
    {
        Ok(s) => s,
        Err(e) => {
            // no python3 on this machine: the schema is still pinned by CI
            eprintln!("skipping python mirror round-trip (python3 unavailable: {e})");
            return;
        }
    };
    assert!(status.success(), "plans.py --roundtrip failed");
    let back = CompressionPlan::load(&out).unwrap();
    assert_eq!(plan, back, "plan changed across the python round-trip");
    let _ = std::fs::remove_dir_all(&tmp);
}
