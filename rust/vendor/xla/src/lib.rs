//! Facade for the XLA/PJRT Rust bindings.
//!
//! This crate declares exactly the API surface `ara-compress`'s `pjrt`
//! backend (`runtime/xla.rs`) compiles against, so `cargo check --features
//! pjrt` succeeds on machines without an XLA toolchain. Every constructor
//! returns an error at runtime; to actually execute on PJRT, substitute the
//! real bindings (the `xla` crate built against `xla_extension`) with a
//! `[patch]` section in the workspace manifest:
//!
//! ```toml
//! [patch.crates-io]  # or a path patch onto rust/vendor/xla
//! xla = { path = "/path/to/real/xla-rs" }
//! ```
//!
//! The method signatures below mirror the binding set the AOT path was
//! developed against (see /opt/xla-example in the build image).

use std::fmt;

/// Binding-level error.
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla facade: {what} is unavailable (this build links the API stub; \
         patch in the real `xla` bindings to enable the pjrt backend)"
    )))
}

/// Element types of literals/buffers the runtime exchanges.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    Other,
}

/// Array shape of a literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host literal (typed, shaped host array).
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        // Constructing a literal is allowed (it is pure host data in the
        // real bindings); all *uses* fail through the stub paths below.
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        stub("Literal::array_shape")
    }

    pub fn ty(&self) -> Result<ElementType> {
        stub("Literal::ty")
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute_b")
    }
}

/// PJRT client (CPU plugin in this repo).
#[derive(Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        stub("PjRtClient::buffer_from_host_buffer")
    }
}

/// Parsed HLO module proto (from HLO text).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}
