"""L2: JAX transformer families with masked-SVD linear modules.

Two families mirror the paper's model zoo at laptop scale (DESIGN.md §2):

  * ``llama`` — RMSNorm, SwiGLU MLP, RoPE, MHA           (LLaMA2 stand-in)
  * ``qwen``  — adds GQA (n_kv_heads < n_heads) + QK-norm (Qwen3 stand-in)

Weight convention: every linear module stores ``W`` of shape ``(out, in)``
and is applied as ``y = x @ Wᵀ``. The seven compressible modules per layer
are ``attn.{wq,wk,wv,wo}`` and ``mlp.{wgate,wup,wdown}`` — exactly the
paper's scope (embeddings / head / norms stay dense).

Masked-SVD form: each compressible ``W (m, n)`` becomes factors
``W_u (m, r)``, ``W_v (r, n)`` with ``r = min(m, n)`` (full rank — the
R_max > 1 training range of Sec. 3.3) plus a rank mask ``(r,)`` supplied at
runtime by the rust allocator. An all-ones mask reproduces the dense module
exactly (up to f32), which is how the R ≥ 1 branch of Eq. 8 is executed with
static shapes; parameter *accounting* for the R≥1 discontinuity lives in
rust (``model/params.rs``).

Every exported graph takes a flat, name-ordered list of arrays (the order is
recorded in the artifact manifest) so the rust runtime binds inputs by name.
"""

import jax
import jax.numpy as jnp

from .kernels import masked_lowrank, rmsnorm, causal_attention

F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

def head_dim(cfg):
    return cfg["d_model"] // cfg["n_heads"]


def kv_dim(cfg):
    return cfg["n_kv_heads"] * head_dim(cfg)


def module_dims(cfg):
    """Ordered list of (name, (m, n)) for the compressible linear modules."""
    d, ff, kvd = cfg["d_model"], cfg["d_ff"], kv_dim(cfg)
    out = []
    for i in range(cfg["n_layers"]):
        p = f"layers.{i}."
        out += [
            (p + "attn.wq", (d, d)),
            (p + "attn.wk", (kvd, d)),
            (p + "attn.wv", (kvd, d)),
            (p + "attn.wo", (d, d)),
            (p + "mlp.wgate", (ff, d)),
            (p + "mlp.wup", (ff, d)),
            (p + "mlp.wdown", (d, ff)),
        ]
    return out


def aux_params(cfg):
    """Ordered list of (name, shape) for non-compressible parameters."""
    d, dh = cfg["d_model"], head_dim(cfg)
    out = [("embed", (cfg["vocab"], d))]
    for i in range(cfg["n_layers"]):
        p = f"layers.{i}."
        out += [(p + "ln1", (d,)), (p + "ln2", (d,))]
        if cfg["family"] == "qwen":
            out += [(p + "qnorm", (dh,)), (p + "knorm", (dh,))]
    out += [("norm_f", (d,)), ("head", (cfg["vocab"], d))]
    return out


def spec_dense(cfg):
    """Flat (name, shape) spec of the dense parameterization."""
    return aux_params(cfg) + [(n, s) for n, s in module_dims(cfg)]


def spec_factored(cfg):
    """Flat (name, shape) spec of the masked-SVD parameterization."""
    out = list(aux_params(cfg))
    for name, (m, n) in module_dims(cfg):
        r = min(m, n)
        out += [(name + ".u", (m, r)), (name + ".v", (r, n))]
    for name, (m, n) in module_dims(cfg):
        out += [("mask:" + name, (min(m, n),))]
    return out


def mask_names(cfg):
    return ["mask:" + name for name, _ in module_dims(cfg)]


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _rope(x, pos, theta):
    """Apply rotary embeddings. x: (b, t, h, dh), pos: (b, t) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) * 2.0 / dh))
    ang = pos[:, :, None].astype(F32) * freqs[None, None, :]     # (b, t, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _linear(params, name, x2d):
    """Apply module `name` to (rows, n): dense, masked-factored, or overridden.

    A callable under params["__linear__"] (used by the LoRA graph) takes
    precedence; otherwise a dense `W` entry, otherwise the masked-SVD pair.
    """
    ov = params.get("__linear__")
    if ov is not None:
        return ov(name, x2d)
    if name in params:
        return x2d @ params[name].T
    return masked_lowrank(x2d, params[name + ".u"], params[name + ".v"],
                          params["mask:" + name])


def _block(cfg, params, i, h, pos):
    """One transformer block. h: (b, t, d), pos: (b, t)."""
    b, t, d = h.shape
    nh, nkv, dh = cfg["n_heads"], cfg["n_kv_heads"], head_dim(cfg)
    p = f"layers.{i}."

    x = rmsnorm(h.reshape(b * t, d), params[p + "ln1"]).reshape(b, t, d)
    x2 = x.reshape(b * t, d)
    q = _linear(params, p + "attn.wq", x2).reshape(b, t, nh, dh)
    k = _linear(params, p + "attn.wk", x2).reshape(b, t, nkv, dh)
    v = _linear(params, p + "attn.wv", x2).reshape(b, t, nkv, dh)
    if cfg["family"] == "qwen":
        q = rmsnorm(q.reshape(-1, dh), params[p + "qnorm"]).reshape(b, t, nh, dh)
        k = rmsnorm(k.reshape(-1, dh), params[p + "knorm"]).reshape(b, t, nkv, dh)
    q = _rope(q, pos, cfg["rope_theta"])
    k = _rope(k, pos, cfg["rope_theta"])
    if nkv != nh:
        rep = nh // nkv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    # pack heads: (b, t, nh, dh) -> (b*nh, t, dh)
    qp = q.transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
    kp = k.transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
    vp = v.transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
    o = causal_attention(qp, kp, vp, float(dh) ** -0.5)
    o = o.reshape(b, nh, t, dh).transpose(0, 2, 1, 3).reshape(b * t, d)
    h = h + _linear(params, p + "attn.wo", o).reshape(b, t, d)

    x = rmsnorm(h.reshape(b * t, d), params[p + "ln2"])
    g = _linear(params, p + "mlp.wgate", x)
    u = _linear(params, p + "mlp.wup", x)
    y = (g * jax.nn.sigmoid(g)) * u                       # SwiGLU
    h = h + _linear(params, p + "mlp.wdown", y).reshape(b, t, d)
    return h


def forward(cfg, params, tokens):
    """Logits for tokens (b, t) int32 → (b, t, vocab)."""
    b, t = tokens.shape
    d = cfg["d_model"]
    h = params["embed"][tokens]                           # (b, t, d)
    pos = jnp.broadcast_to(jnp.arange(t, dtype=I32)[None, :], (b, t))
    for i in range(cfg["n_layers"]):
        h = _block(cfg, params, i, h, pos)
    h = rmsnorm(h.reshape(b * t, d), params["norm_f"])
    return (h @ params["head"].T).reshape(b, t, cfg["vocab"])


def nll_tokens(cfg, params, tokens, targets):
    """Per-position negative log-likelihood (b, t)."""
    logits = forward(cfg, params, tokens)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - picked


def mean_loss(cfg, params, tokens, targets):
    return jnp.mean(nll_tokens(cfg, params, tokens, targets))


# ---------------------------------------------------------------------------
# Exported graph builders — each returns (fn, input_spec, output_names)
# where fn takes the flat array list in input_spec order.
# ---------------------------------------------------------------------------

def _batch_spec(cfg, batch, seq):
    return [("tokens", (batch, seq), I32), ("targets", (batch, seq), I32)]


def _to_spec3(pairs):
    return [(n, s, F32) for n, s in pairs]


def _bind(names):
    def unflatten(arrays):
        return dict(zip(names, arrays))
    return unflatten


def make_train_step(cfg):
    """Dense fwd+bwd: (weights…, tokens, targets) → (loss, grads…)."""
    wspec = spec_dense(cfg)
    spec = _to_spec3(wspec) + _batch_spec(cfg, cfg["batch_train"], cfg["seq_train"])
    names = [n for n, *_ in spec]
    nw = len(wspec)
    unflatten = _bind(names)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, targets = params.pop("tokens"), params.pop("targets")

        def loss_fn(wlist):
            p = dict(zip([n for n, _ in wspec], wlist))
            return mean_loss(cfg, p, tokens, targets)

        loss, grads = jax.value_and_grad(loss_fn)(list(arrays[:nw]))
        return (loss, *grads)

    outs = ["loss"] + ["grad:" + n for n, _ in wspec]
    return fn, spec, outs


def make_score_dense(cfg):
    """Dense per-token NLL: (weights…, tokens, targets) → (nll[b,t],)."""
    wspec = spec_dense(cfg)
    spec = _to_spec3(wspec) + _batch_spec(cfg, cfg["batch_eval"], cfg["seq_eval"])
    names = [n for n, *_ in spec]
    unflatten = _bind(names)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, targets = params.pop("tokens"), params.pop("targets")
        return (nll_tokens(cfg, params, tokens, targets),)

    return fn, spec, ["nll"]


def make_score_masked(cfg):
    """Masked-factored per-token NLL (allocation-time + compressed eval)."""
    wspec = spec_factored(cfg)
    spec = _to_spec3(wspec) + _batch_spec(cfg, cfg["batch_eval"], cfg["seq_eval"])
    names = [n for n, *_ in spec]
    unflatten = _bind(names)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, targets = params.pop("tokens"), params.pop("targets")
        return (nll_tokens(cfg, params, tokens, targets),)

    return fn, spec, ["nll"]


def make_mask_fwd_grad(cfg):
    """The allocation-training step: loss + ∂L/∂mask for every module.

    Masks arrive as runtime inputs (binary under STE — rust decides); the
    gradient w.r.t. the mask vector is exact, and rust chains it through
    each method's parameterization (ARA staircase, ARS Gumbel-Sigmoid,
    Dobi tanh) per Eq. 5.
    """
    wspec = spec_factored(cfg)
    spec = _to_spec3(wspec) + _batch_spec(cfg, cfg["batch_eval"], cfg["seq_eval"])
    names = [n for n, *_ in spec]
    mnames = mask_names(cfg)
    midx = [names.index(mn) for mn in mnames]
    unflatten = _bind(names)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, targets = params["tokens"], params["targets"]

        def loss_fn(masks):
            p = dict(params)
            p.pop("tokens"), p.pop("targets")
            for mn, mv in zip(mnames, masks):
                p[mn] = mv
            return mean_loss(cfg, p, tokens, targets)

        loss, grads = jax.value_and_grad(loss_fn)([arrays[i] for i in midx])
        return (loss, *grads)

    outs = ["loss"] + ["grad:" + mn for mn in mnames]
    return fn, spec, outs


def make_lora_step(cfg):
    """LoRA recovery step on the compressed model: loss + grads w.r.t. A,B.

    Forward per module: y = masked_lowrank(x, W_u, W_v, m) + (x@Aᵀ)@Bᵀ with
    A (lr, n), B (m, lr). Frozen factors+masks are runtime inputs.
    """
    lr = cfg["lora_rank"]
    wspec = spec_factored(cfg)
    lspec = []
    for name, (m, n) in module_dims(cfg):
        lspec += [("lora_a:" + name, (lr, n)), ("lora_b:" + name, (m, lr))]
    spec = _to_spec3(wspec + lspec) + _batch_spec(cfg, cfg["batch_train"], cfg["seq_train"])
    names = [n for n, *_ in spec]
    lnames = [n for n, _ in lspec]
    lidx = [names.index(ln) for ln in lnames]
    unflatten = _bind(names)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, targets = params["tokens"], params["targets"]
        base = {k: v for k, v in params.items()
                if not (k.startswith("lora_") or k in ("tokens", "targets"))}

        def loss_fn(loras):
            lp = dict(zip(lnames, loras))

            # Shadow _linear with a LoRA-augmented version via params dict:
            def lin(name, x2d):
                y = masked_lowrank(x2d, base[name + ".u"], base[name + ".v"],
                                   base["mask:" + name])
                return y + (x2d @ lp["lora_a:" + name].T) @ lp["lora_b:" + name].T

            p = dict(base)
            p["__linear__"] = lin
            return mean_loss(cfg, p, tokens, targets)

        loss, grads = jax.value_and_grad(loss_fn)([arrays[i] for i in lidx])
        return (loss, *grads)

    outs = ["loss"] + ["grad:" + ln for ln in lnames]
    return fn, spec, outs


def make_calibrate(cfg):
    """Calibration pass: accumulate the per-module input Gram matrices
    H = Σ xᵀx over a batch (Sec. 3.1 whitening). Rust sums over batches and
    hands H to the Cholesky/SVD pipeline — activations never leave the
    device as raw tensors, only as (n, n) statistics."""
    wspec = spec_dense(cfg)
    spec = _to_spec3(wspec) + [
        ("tokens", (cfg["batch_eval"], cfg["seq_eval"]), I32)
    ]
    names = [n for n, *_ in spec]
    unflatten = _bind(names)
    mods = module_dims(cfg)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens = params.pop("tokens")
        caps = {}

        def lin(name, x2d):
            caps[name] = x2d.T @ x2d
            return x2d @ params[name].T

        p = dict(params)
        p["__linear__"] = lin
        logits = forward(cfg, p, tokens)
        # keep every weight live: XLA prunes unused parameters from the
        # compiled signature, which would break name-bound feeding (the
        # head/final-norm/last-wdown path is otherwise dead code here).
        anchor = jnp.mean(logits)
        return tuple(caps[name] for name, _ in mods) + (anchor,)

    outs = ["h:" + name for name, _ in mods] + ["anchor"]
    return fn, spec, outs


# ---------------------------------------------------------------------------
# Serving graphs: allocation-specialized prefill / decode with KV cache
# ---------------------------------------------------------------------------

def spec_alloc(cfg, alloc):
    """Weight spec for an allocation: dense W or truncated (W_u, W_v) per module."""
    out = list(aux_params(cfg))
    for name, (m, n) in module_dims(cfg):
        a = alloc["modules"][name]
        if a.get("dense", False):
            out.append((name, (m, n)))
        else:
            k = int(a["rank"])
            out += [(name + ".u", (m, k)), (name + ".v", (k, n))]
    return out


def _linear_alloc(params, name, x2d):
    if name in params:
        return x2d @ params[name].T
    t = x2d @ params[name + ".v"].T
    return t @ params[name + ".u"].T


def _cache_spec(cfg, batch):
    s, dh, nkv = cfg["max_decode_seq"], head_dim(cfg), cfg["n_kv_heads"]
    out = []
    for i in range(cfg["n_layers"]):
        out += [(f"kcache.{i}", (batch, nkv, s, dh), F32),
                (f"vcache.{i}", (batch, nkv, s, dh), F32)]
    return out


def _attend_cache(cfg, q, kc, vc, lens, starts=None):
    """q: (b, nh, dh); kc/vc: (b, nkv, s, dh); lens: (b,) valid lengths.

    Returns (b, nh, dh) attention over cached slots in [starts, lens)
    (starts=None means 0 — the whole prefix).
    """
    b, nh, dh = q.shape
    nkv, s = kc.shape[1], kc.shape[2]
    if nkv != nh:
        rep = nh // nkv
        kc = jnp.repeat(kc, rep, axis=1)
        vc = jnp.repeat(vc, rep, axis=1)
    scores = jnp.einsum("bhd,bhsd->bhs", q, kc) / jnp.sqrt(F32(dh))
    ramp = jnp.arange(s, dtype=I32)[None, None, :]
    valid = ramp < lens[:, None, None]
    if starts is not None:
        valid = valid & (ramp >= starts[:, None, None])
    scores = jnp.where(valid, scores, jnp.float32(-1e30))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhs,bhsd->bhd", p, vc)


def make_decode(cfg, alloc, batch):
    """One decode step: (weights…, caches…, tokens[b], lens[b], starts[b]) →
    (logits[b,v], caches'…). `lens` is the cache slot the new token is
    written to (and the highest slot attended); `starts` is the first valid
    slot of the request's window — slots below it hold left-pad garbage
    from the ragged prefill and are masked out. The rope position is the
    relative `lens - starts`; `starts = 0` reproduces the original math.
    """
    wspec = _to_spec3(spec_alloc(cfg, alloc))
    cspec = _cache_spec(cfg, batch)
    spec = wspec + cspec + [("tokens", (batch,), I32), ("lens", (batch,), I32),
                            ("starts", (batch,), I32)]
    names = [n for n, *_ in spec]
    unflatten = _bind(names)
    d, nh, nkv, dh = cfg["d_model"], cfg["n_heads"], cfg["n_kv_heads"], head_dim(cfg)

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, lens = params["tokens"], params["lens"]
        starts = params["starts"]
        b = batch
        h = params["embed"][tokens]                          # (b, d)
        pos = lens - starts                                  # (b,) relative
        new_caches = []
        for i in range(cfg["n_layers"]):
            p = f"layers.{i}."
            x = rmsnorm(h, params[p + "ln1"])
            q = _linear_alloc(params, p + "attn.wq", x).reshape(b, nh, dh)
            k = _linear_alloc(params, p + "attn.wk", x).reshape(b, nkv, dh)
            v = _linear_alloc(params, p + "attn.wv", x).reshape(b, nkv, dh)
            if cfg["family"] == "qwen":
                q = rmsnorm(q.reshape(-1, dh), params[p + "qnorm"]).reshape(b, nh, dh)
                k = rmsnorm(k.reshape(-1, dh), params[p + "knorm"]).reshape(b, nkv, dh)
            q = _rope(q[:, None], pos[:, None], cfg["rope_theta"])[:, 0]
            k = _rope(k[:, None], pos[:, None], cfg["rope_theta"])[:, 0]
            kc, vc = params[f"kcache.{i}"], params[f"vcache.{i}"]
            # scatter the new k/v at per-seq position `lens`
            kc = _scatter_cache(kc, k, lens)
            vc = _scatter_cache(vc, v, lens)
            new_caches += [kc, vc]
            o = _attend_cache(cfg, q, kc, vc, lens + 1, starts)
            h = h + _linear_alloc(params, p + "attn.wo", o.reshape(b, d))
            x = rmsnorm(h, params[p + "ln2"])
            g = _linear_alloc(params, p + "mlp.wgate", x)
            u = _linear_alloc(params, p + "mlp.wup", x)
            h = h + _linear_alloc(params, p + "mlp.wdown", (g * jax.nn.sigmoid(g)) * u)
        h = rmsnorm(h, params["norm_f"])
        logits = h @ params["head"].T
        return (logits, *new_caches)

    outs = ["logits"] + [n for n, *_ in cspec]
    return fn, spec, outs


def _scatter_cache(cache, kv, lens):
    """cache (b, nkv, s, dh) ← kv (b, nkv, dh) at per-seq position lens (b,)."""
    def one(c, x, i):
        return jax.lax.dynamic_update_slice_in_dim(c, x[:, None, :], i, axis=1)
    return jax.vmap(one)(cache, kv, lens)


def _masked_attention(q, k, v, scale, mask):
    """causal_attention with an explicit (bh, t, t) boolean mask."""
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    scores = jnp.where(mask, scores, jnp.float32(-1e30))
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v)


def make_prefill(cfg, alloc, batch):
    """Ragged prompt prefill: (weights…, tokens[b,P], lens[b]) →
    (logits_last[b,v], caches…).

    Left-pad masking contract (mirrored by rust/src/runtime/programs.rs):
    each prompt occupies the rightmost ``lens[i]`` slots of its fixed-length
    P = cfg["prefill_len"] row; pad slots get negative rope positions and
    are excluded from attention as keys, so every row's outputs depend only
    on its real tokens. Caches are written at the padded slot positions —
    decode masks slots below ``starts = P - lens``. ``lens = P`` reproduces
    the original fixed-length prefill math exactly.
    """
    P = cfg["prefill_len"]
    wspec = _to_spec3(spec_alloc(cfg, alloc))
    spec = wspec + [("tokens", (batch, P), I32), ("lens", (batch,), I32)]
    names = [n for n, *_ in spec]
    unflatten = _bind(names)
    d, nh, nkv, dh = cfg["d_model"], cfg["n_heads"], cfg["n_kv_heads"], head_dim(cfg)
    S = cfg["max_decode_seq"]

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, lens = params["tokens"], params["lens"]
        b, t = batch, P
        h = params["embed"][tokens]
        pos = jnp.arange(t, dtype=I32)[None, :] - (t - lens[:, None])  # (b, t)
        kvalid = jnp.arange(t, dtype=I32)[None, :] >= (t - lens[:, None])
        causal = jnp.tril(jnp.ones((t, t), dtype=bool))
        # (b, t, t) → broadcast over heads to (b*nh, t, t)
        mask = causal[None, :, :] & kvalid[:, None, :]
        mask_bh = jnp.repeat(mask, nh, axis=0).reshape(b * nh, t, t)
        caches = []
        for i in range(cfg["n_layers"]):
            p = f"layers.{i}."
            x2 = rmsnorm(h.reshape(b * t, d), params[p + "ln1"])
            q = _linear_alloc(params, p + "attn.wq", x2).reshape(b, t, nh, dh)
            k = _linear_alloc(params, p + "attn.wk", x2).reshape(b, t, nkv, dh)
            v = _linear_alloc(params, p + "attn.wv", x2).reshape(b, t, nkv, dh)
            if cfg["family"] == "qwen":
                q = rmsnorm(q.reshape(-1, dh), params[p + "qnorm"]).reshape(b, t, nh, dh)
                k = rmsnorm(k.reshape(-1, dh), params[p + "knorm"]).reshape(b, t, nkv, dh)
            q = _rope(q, pos, cfg["rope_theta"])
            k = _rope(k, pos, cfg["rope_theta"])
            kr, vr = k, v
            if nkv != nh:
                rep = nh // nkv
                kr = jnp.repeat(k, rep, axis=2)
                vr = jnp.repeat(v, rep, axis=2)
            qp = q.transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
            kp = kr.transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
            vp = vr.transpose(0, 2, 1, 3).reshape(b * nh, t, dh)
            o = _masked_attention(qp, kp, vp, float(dh) ** -0.5, mask_bh)
            o = o.reshape(b, nh, t, dh).transpose(0, 2, 1, 3).reshape(b * t, d)
            h = h + _linear_alloc(params, p + "attn.wo", o).reshape(b, t, d)
            x2 = rmsnorm(h.reshape(b * t, d), params[p + "ln2"])
            g = _linear_alloc(params, p + "mlp.wgate", x2)
            u = _linear_alloc(params, p + "mlp.wup", x2)
            h = h + _linear_alloc(params, p + "mlp.wdown",
                                  (g * jax.nn.sigmoid(g)) * u).reshape(b, t, d)
            # write caches: (b, t, nkv, dh) -> (b, nkv, S, dh), zero beyond P
            kc = jnp.zeros((b, nkv, S, dh), F32).at[:, :, :t, :].set(
                k.transpose(0, 2, 1, 3))
            vc = jnp.zeros((b, nkv, S, dh), F32).at[:, :, :t, :].set(
                v.transpose(0, 2, 1, 3))
            caches += [kc, vc]
        hf = rmsnorm(h[:, -1, :], params["norm_f"])
        logits = hf @ params["head"].T
        return (logits, *caches)

    outs = ["logits"] + [n for n, *_ in _cache_spec(cfg, batch)]
    return fn, spec, outs


def _pool_spec(cfg, block_len, num_blocks):
    rows, width = num_blocks * block_len, kv_dim(cfg)
    out = []
    for i in range(cfg["n_layers"]):
        out += [(f"kpool.{i}", (rows, width), F32),
                (f"vpool.{i}", (rows, width), F32)]
    return out


def make_decode_paged(cfg, alloc, batch, block_len, num_blocks):
    """One decode step over a **block-paged KV pool** (mirrors
    ``rust/src/runtime/programs.rs:decode_paged`` — the continuous-batching
    scheduler's hot path; artifact name
    ``decode_paged_<alloc>_b<B>_blk<block_len>x<num_blocks>``).

    Per layer the pool is a 2-D row table ``(num_blocks·block_len,
    nkv·head_dim)``: row ``r`` holds every kv-head's vector for token slot
    ``r % block_len`` of block ``r // block_len``. Block 0 is the reserved
    scratch block parked slots write into. Inputs per slot: ``tokens[b]``,
    ``lens[b]`` — the **virtual** write/attend position (the paged layout
    drops the contiguous path's left-pad, so the rope position is ``lens``
    and there is no ``starts``), ``rows[b]`` — the physical pool row the
    new k/v is scattered to (``btable[i, lens[i]//block_len]·block_len +
    lens[i] % block_len``, precomputed by the scheduler), and
    ``btable[b, bps]`` — the block table the attention window is gathered
    through (padded entries point at the scratch block and are masked).
    Virtual slots above ``lens[i]`` are masked, so stale rows never
    contribute. With ``block_len = max_decode_seq`` (one block per
    sequence) every token stream is bitwise identical to ``make_decode``.
    """
    wspec = _to_spec3(spec_alloc(cfg, alloc))
    pspec = _pool_spec(cfg, block_len, num_blocks)
    bps = -(-cfg["max_decode_seq"] // block_len)  # blocks per sequence
    S = bps * block_len
    spec = wspec + pspec + [("tokens", (batch,), I32), ("lens", (batch,), I32),
                            ("rows", (batch,), I32), ("btable", (batch, bps), I32)]
    names = [n for n, *_ in spec]
    unflatten = _bind(names)
    d, nh, nkv, dh = cfg["d_model"], cfg["n_heads"], cfg["n_kv_heads"], head_dim(cfg)
    width = nkv * dh

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, lens = params["tokens"], params["lens"]
        wrows, btable = params["rows"], params["btable"]
        b = batch
        h = params["embed"][tokens]                          # (b, d)
        pos = lens                                           # virtual rope position
        new_pools = []
        for i in range(cfg["n_layers"]):
            p = f"layers.{i}."
            x = rmsnorm(h, params[p + "ln1"])
            q = _linear_alloc(params, p + "attn.wq", x).reshape(b, nh, dh)
            k = _linear_alloc(params, p + "attn.wk", x).reshape(b, nkv, dh)
            v = _linear_alloc(params, p + "attn.wv", x).reshape(b, nkv, dh)
            if cfg["family"] == "qwen":
                q = rmsnorm(q.reshape(-1, dh), params[p + "qnorm"]).reshape(b, nh, dh)
                k = rmsnorm(k.reshape(-1, dh), params[p + "knorm"]).reshape(b, nkv, dh)
            q = _rope(q[:, None], pos[:, None], cfg["rope_theta"])[:, 0]
            k = _rope(k[:, None], pos[:, None], cfg["rope_theta"])[:, 0]
            # scatter the new k/v rows into the pool (the rust interpreter
            # resolves duplicate parked-slot rows to the highest batch index)
            kp = params[f"kpool.{i}"].at[wrows].set(k.reshape(b, width))
            vp = params[f"vpool.{i}"].at[wrows].set(v.reshape(b, width))
            new_pools += [kp, vp]
            # gather each slot's window through its block table:
            # (b, bps) block ids → (b, S) physical rows → (b, nkv, S, dh)
            prow = (btable * block_len)[:, :, None] \
                + jnp.arange(block_len, dtype=I32)[None, None, :]
            prow = prow.reshape(b, S)
            kc = kp[prow].reshape(b, S, nkv, dh).transpose(0, 2, 1, 3)
            vc = vp[prow].reshape(b, S, nkv, dh).transpose(0, 2, 1, 3)
            # attend over virtual slots ≤ lens (starts = 0 in paged layout)
            o = _attend_cache(cfg, q, kc, vc, lens + 1, None)
            h = h + _linear_alloc(params, p + "attn.wo", o.reshape(b, d))
            x = rmsnorm(h, params[p + "ln2"])
            g = _linear_alloc(params, p + "mlp.wgate", x)
            u = _linear_alloc(params, p + "mlp.wup", x)
            h = h + _linear_alloc(params, p + "mlp.wdown", (g * jax.nn.sigmoid(g)) * u)
        h = rmsnorm(h, params["norm_f"])
        logits = h @ params["head"].T
        return (logits, *new_pools)

    outs = ["logits"] + [n for n, *_ in pspec]
    return fn, spec, outs


def make_decode_verify(cfg, alloc, batch, block_len, num_blocks, window):
    """Speculative **verify** pass over the paged pool (mirrors
    ``rust/src/runtime/programs.rs:decode_verify``; artifact name
    ``decode_verify_<alloc>_b<B>_blk<block_len>x<num_blocks>_k<window>``).

    Scores a ``(b, window)`` token window in one call: window slot ``j`` of
    sequence ``i`` sits at virtual position ``lens[i] + j``. Per layer all
    ``window`` new K/V rows are scattered at ``rows[i·window + j]``
    **before** the block-table gather, so within-window attention reads the
    freshly written rows; per-position masking (virtual slot ≤ ``lens[i] +
    j``) gives each window slot exactly the prefix a sequential one-token
    ``make_decode_paged`` step would see. Because every kernel reduces along
    row-independent axes, ``logits[i, j]`` is bitwise identical to the
    sequential step's logits — the self-speculative acceptance contract
    (DESIGN.md §8). Returns logits ``(b, window, vocab)`` plus the updated
    pools.
    """
    wspec = _to_spec3(spec_alloc(cfg, alloc))
    pspec = _pool_spec(cfg, block_len, num_blocks)
    bps = -(-cfg["max_decode_seq"] // block_len)  # blocks per sequence
    S = bps * block_len
    W = window
    spec = wspec + pspec + [("tokens", (batch, W), I32), ("lens", (batch,), I32),
                            ("rows", (batch * W,), I32),
                            ("btable", (batch, bps), I32)]
    names = [n for n, *_ in spec]
    unflatten = _bind(names)
    d, nh, nkv, dh = cfg["d_model"], cfg["n_heads"], cfg["n_kv_heads"], head_dim(cfg)
    width = nkv * dh

    def fn(*arrays):
        params = unflatten(arrays)
        tokens, lens = params["tokens"], params["lens"]
        wrows, btable = params["rows"], params["btable"]
        b = batch
        h = params["embed"][tokens]                          # (b, W, d)
        pos = lens[:, None] + jnp.arange(W, dtype=I32)[None, :]  # (b, W)
        new_pools = []
        for i in range(cfg["n_layers"]):
            p = f"layers.{i}."
            x2 = rmsnorm(h.reshape(b * W, d), params[p + "ln1"])
            q = _linear_alloc(params, p + "attn.wq", x2).reshape(b, W, nh, dh)
            k = _linear_alloc(params, p + "attn.wk", x2).reshape(b, W, nkv, dh)
            v = _linear_alloc(params, p + "attn.wv", x2).reshape(b, W, nkv, dh)
            if cfg["family"] == "qwen":
                q = rmsnorm(q.reshape(-1, dh), params[p + "qnorm"]).reshape(b, W, nh, dh)
                k = rmsnorm(k.reshape(-1, dh), params[p + "knorm"]).reshape(b, W, nkv, dh)
            q = _rope(q, pos, cfg["rope_theta"])
            k = _rope(k, pos, cfg["rope_theta"])
            # scatter all W rows, then gather: write-before-gather makes the
            # within-window prefix visible to later window slots
            kp = params[f"kpool.{i}"].at[wrows].set(k.reshape(b * W, width))
            vp = params[f"vpool.{i}"].at[wrows].set(v.reshape(b * W, width))
            new_pools += [kp, vp]
            prow = (btable * block_len)[:, :, None] \
                + jnp.arange(block_len, dtype=I32)[None, None, :]
            prow = prow.reshape(b, S)
            kc = kp[prow].reshape(b, S, nkv, dh).transpose(0, 2, 1, 3)
            vc = vp[prow].reshape(b, S, nkv, dh).transpose(0, 2, 1, 3)
            if nkv != nh:
                rep = nh // nkv
                kc = jnp.repeat(kc, rep, axis=1)
                vc = jnp.repeat(vc, rep, axis=1)
            # per-position mask: window slot j attends virtual slots ≤ lens+j
            ramp = jnp.arange(S, dtype=I32)[None, None, :]
            mask = ramp <= pos[:, :, None]                   # (b, W, S)
            mask_bh = jnp.broadcast_to(mask[:, None], (b, nh, W, S)) \
                .reshape(b * nh, W, S)
            qp = q.transpose(0, 2, 1, 3).reshape(b * nh, W, dh)
            kp3 = kc.reshape(b * nh, S, dh)
            vp3 = vc.reshape(b * nh, S, dh)
            o = _masked_attention(qp, kp3, vp3, float(dh) ** -0.5, mask_bh)
            o = o.reshape(b, nh, W, dh).transpose(0, 2, 1, 3).reshape(b * W, d)
            h = h + _linear_alloc(params, p + "attn.wo", o).reshape(b, W, d)
            x2 = rmsnorm(h.reshape(b * W, d), params[p + "ln2"])
            g = _linear_alloc(params, p + "mlp.wgate", x2)
            u = _linear_alloc(params, p + "mlp.wup", x2)
            h = h + _linear_alloc(params, p + "mlp.wdown",
                                  (g * jax.nn.sigmoid(g)) * u).reshape(b, W, d)
        hf = rmsnorm(h.reshape(b * W, d), params["norm_f"])
        logits = (hf @ params["head"].T).reshape(b, W, cfg["vocab"])
        return (logits, *new_pools)

    outs = ["logits"] + [n for n, *_ in pspec]
    return fn, spec, outs
