"""AOT compile path: lower every exported graph to HLO text + manifest.

Runs ONCE under `make artifacts`. For each model preset in
configs/models.json this emits, under artifacts/<model>/:

  train_step      — dense fwd+bwd (pre-training the substrate LM)
  score_dense     — dense per-token NLL (PPL / zero-shot for dense+pruning)
  score_masked    — masked-SVD per-token NLL (compressed eval)
  mask_fwd_grad   — loss + ∂L/∂mask per module (allocation training core)
  lora_step       — loss + ∂L/∂(A,B) (LoRA recovery, Table 6)
  decode_<alloc>_b<B> / prefill_<alloc>_b<B>   (serving models only)

plus <name>.manifest.json describing the exact input/output tensor order
(name, shape, dtype) — the rust runtime binds by name, never by position.

Interchange is HLO TEXT, not a serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Serving graphs are specialized to rank allocations. Allocation JSONs are
looked up in configs/allocations/<model>.<alloc>.json (written there by the
rust allocator via `ara export-alloc`, or checked-in defaults); uniform/dense
allocations are computed here; a missing ARA allocation falls back to a
paper-shaped heuristic (Fig. 4 structure: v/down dense, q/k compressed hard)
and the resolved JSON is dumped to artifacts/allocations/ for inspection.
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model as M
from . import plans


def to_hlo_text(lowered) -> str:
    # return_tuple=False so PJRT can untuple multi-output executables into
    # separate device buffers (the serving engine keeps KV caches device-
    # resident across decode steps); the rust runtime also handles the
    # single-tuple-buffer case defensively.
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _dtype_name(dt):
    return "i32" if dt == M.I32 else "f32"


def export(fn, spec, outs, outdir, name):
    """Lower `fn` with the given input spec and write HLO text + manifest."""
    args = [jax.ShapeDtypeStruct(shape, dt) for (_, shape, dt) in spec]
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    os.makedirs(outdir, exist_ok=True)
    with open(os.path.join(outdir, name + ".hlo.txt"), "w") as f:
        f.write(text)
    manifest = {
        "name": name,
        "inputs": [
            {"name": n, "shape": list(shape), "dtype": _dtype_name(dt)}
            for (n, shape, dt) in spec
        ],
        "outputs": outs,
    }
    with open(os.path.join(outdir, name + ".manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  {name}: {len(spec)} inputs, {len(outs)} outputs, "
          f"{len(text) // 1024} KiB hlo")


# ---------------------------------------------------------------------------
# Allocations for serving specialization
# ---------------------------------------------------------------------------

def uniform_alloc(cfg, ratio):
    """SVD-LLM-style uniform allocation: same parameter ratio per module."""
    mods = {}
    for name, (m, n) in M.module_dims(cfg):
        k = max(1, int(ratio * m * n / (m + n)))
        mods[name] = {"dense": False, "rank": min(k, min(m, n))}
    return {"name": f"uniform-{int(ratio*100)}", "modules": mods}


def dense_alloc(cfg):
    return {"name": "dense",
            "modules": {name: {"dense": True} for name, _ in M.module_dims(cfg)}}


def heuristic_ara_alloc(cfg, ratio):
    """Paper-shaped fallback (Fig. 4): keep v/down dense where the budget
    allows, compress q/k hardest, meet the global compressible budget."""
    dims = M.module_dims(cfg)
    total = sum(m * n for _, (m, n) in dims)
    budget = ratio * total
    prefer_dense = [name for name, _ in dims
                    if name.endswith(".wv") or name.endswith(".wdown")]
    weight = {"wq": 0.45, "wk": 0.45, "wv": 1.0, "wo": 0.9,
              "wgate": 1.1, "wup": 0.9, "wdown": 1.0}

    dense_set = set()
    for name in prefer_dense:          # greedily keep dense while affordable
        mn = dict(dims)[name][0] * dict(dims)[name][1]
        rest = [(nm, d) for nm, d in dims if nm not in dense_set | {name}]
        min_rest = sum(1 * (m + n) for _, (m, n) in rest)   # rank-1 floor
        if sum(dict(dims)[d][0] * dict(dims)[d][1] for d in dense_set) + mn \
                + min_rest <= budget:
            dense_set.add(name)

    used = sum(dict(dims)[d][0] * dict(dims)[d][1] for d in dense_set)
    rest = [(nm, d) for nm, d in dims if nm not in dense_set]
    wsum = sum(weight[nm.split(".")[-1]] * m * n for nm, (m, n) in rest) or 1.0

    mods = {}
    for name, (m, n) in dims:
        if name in dense_set:
            mods[name] = {"dense": True}
            continue
        w = weight[name.split(".")[-1]]
        share = (budget - used) * (w * m * n) / wsum
        k = max(1, min(int(share / (m + n)), min(m, n)))
        mods[name] = {"dense": False, "rank": k}
    return {"name": f"ara-{int(ratio*100)}", "modules": mods}


def resolve_alloc(cfg, alloc_name, configs_dir, artifacts_dir):
    path = os.path.join(configs_dir, "allocations",
                        f"{cfg['name']}.{alloc_name}.json")
    if os.path.exists(path):
        # plans.load_alloc_file accepts both versioned CompressionPlan
        # documents (rust `ara compress --out`, schema mirrored in
        # plans.py) and legacy bare-Allocation JSON
        alloc, plan = plans.load_alloc_file(path)
        prov = f" (plan {plan['spec']}, schema v{plan['schema_version']})" \
            if plan else ""
        print(f"  [alloc] {alloc_name}: loaded {path}{prov}")
        return alloc
    if alloc_name == "dense":
        alloc = dense_alloc(cfg)
    elif alloc_name.startswith("uniform-"):
        alloc = uniform_alloc(cfg, int(alloc_name.split("-")[1]) / 100.0)
    elif alloc_name.startswith("ara-"):
        alloc = heuristic_ara_alloc(cfg, int(alloc_name.split("-")[1]) / 100.0)
        print(f"  [alloc] {alloc_name}: no {path}; using paper-shaped heuristic")
    else:
        raise ValueError(alloc_name)
    dump_dir = os.path.join(artifacts_dir, "allocations")
    os.makedirs(dump_dir, exist_ok=True)
    with open(os.path.join(dump_dir, f"{cfg['name']}.{alloc_name}.json"), "w") as f:
        json.dump(alloc, f, indent=1)
    return alloc


SERVING_ALLOCS = ["dense", "uniform-80", "uniform-60", "ara-80", "ara-60"]


def export_model(cfg, outroot, configs_dir, skip_serving=False):
    outdir = os.path.join(outroot, cfg["name"])
    print(f"[{cfg['name']}] family={cfg['family']} d={cfg['d_model']} "
          f"L={cfg['n_layers']}")
    export(*M.make_train_step(cfg), outdir, "train_step")
    export(*M.make_calibrate(cfg), outdir, "calibrate")
    export(*M.make_score_dense(cfg), outdir, "score_dense")
    export(*M.make_score_masked(cfg), outdir, "score_masked")
    export(*M.make_mask_fwd_grad(cfg), outdir, "mask_fwd_grad")
    export(*M.make_lora_step(cfg), outdir, "lora_step")
    if cfg.get("serving") and not skip_serving:
        for alloc_name in SERVING_ALLOCS:
            alloc = resolve_alloc(cfg, alloc_name, configs_dir, outroot)
            for b in cfg["decode_batches"]:
                export(*M.make_decode(cfg, alloc, b), outdir,
                       f"decode_{alloc_name}_b{b}")
                export(*M.make_prefill(cfg, alloc, b), outdir,
                       f"prefill_{alloc_name}_b{b}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--configs", default="../configs")
    ap.add_argument("--only", default=None, help="export a single model preset")
    ap.add_argument("--skip-serving", action="store_true")
    args = ap.parse_args()

    with open(os.path.join(args.configs, "models.json")) as f:
        presets = json.load(f)["models"]
    exported = []
    for cfg in presets:
        if args.only and cfg["name"] != args.only:
            continue
        export_model(cfg, args.outdir, args.configs, args.skip_serving)
        exported.append(cfg["name"])
    with open(os.path.join(args.outdir, "index.json"), "w") as f:
        json.dump({"models": exported, "serving_allocs": SERVING_ALLOCS}, f,
                  indent=1)
    print(f"exported: {exported}")


if __name__ == "__main__":
    main()
