# Build-time compile path: JAX/Pallas model definitions + AOT lowering.
# Nothing in this package is imported at runtime; `aot.py` runs once under
# `make artifacts` and emits HLO text + manifests consumed by the rust layer.
