"""Pallas kernel: fused masked low-rank linear — the paper's compute hot-spot.

Computes `y = ((x @ W_vᵀ) ⊙ m) @ W_uᵀ` (the R<1 branch of Eq. 8) with the
rank dimension tiled so the low-rank intermediate `t = x·W_vᵀ` never
round-trips to HBM: each grid step loads a `(br, n)` slab of W_v and a
`(bm, br)` slab of W_u into VMEM, applies the mask while the tile is
resident (it rides the same DMA as W_v), and accumulates into the output
block. This is the TPU re-think of the CUDA shared-memory staging a GPU
implementation would use (DESIGN.md §Hardware-Adaptation):

  grid = (m_blocks, r_blocks)      — r is the innermost (sequential) axis
  x      : (rows, n)   block (rows, n)       broadcast over the grid
  w_v    : (r, n)      block (br, n)         indexed by r-step
  mask   : (r,)        block (br,)           indexed by r-step
  w_u    : (m, r)      block (bm, br)        indexed by (m-step, r-step)
  out    : (rows, m)   block (rows, bm)      revisited across r-steps

VMEM budget per step ≈ rows·n + br·n + bm·br + rows·bm floats; block sizes
are chosen by `_pick_block` to stay under ~2 MiB for the shapes we compile.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO and correctness is checked
against `ref.masked_lowrank` by pytest. Real-TPU efficiency is estimated
from the BlockSpec footprint in EXPERIMENTS.md §Perf.

The backward (custom_vjp) is expressed with jnp matmuls: it only ever runs
inside the build-time-lowered `mask_fwd_grad` / `lora_step` graphs, where
XLA fuses it; the forward is the serving/eval hot path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim, target):
    """Largest divisor of `dim` that is <= target (>=1)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _kernel(x_ref, wv_ref, mask_ref, wu_ref, o_ref, *, acc_steps):
    """One (m-block, r-block) grid step: o += ((x @ wv_blkᵀ) ⊙ m_blk) @ wu_blkᵀ."""
    rstep = pl.program_id(1)

    @pl.when(rstep == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    t = jnp.dot(x_ref[...], wv_ref[...].T)          # (rows, br) — stays in VMEM
    t = t * mask_ref[...][None, :]                  # mask applied tile-resident
    o_ref[...] += jnp.dot(t, wu_ref[...].T)         # (rows, bm) accumulate


def _forward(x, w_u, w_v, mask, *, bm_target=128, br_target=64):
    rows, n = x.shape
    m, r = w_u.shape
    bm = _pick_block(m, bm_target)
    br = _pick_block(r, br_target)
    grid = (m // bm, r // br)
    return pl.pallas_call(
        functools.partial(_kernel, acc_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, n), lambda i, j: (0, 0)),
            pl.BlockSpec((br, n), lambda i, j: (j, 0)),
            pl.BlockSpec((br,), lambda i, j: (j,)),
            pl.BlockSpec((bm, br), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((rows, bm), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((rows, m), x.dtype),
        interpret=True,
    )(x, w_v, mask, w_u)


@jax.custom_vjp
def masked_lowrank(x, w_u, w_v, mask):
    """Fused masked low-rank linear: ((x @ W_vᵀ) ⊙ m) @ W_uᵀ.

    Shapes: x (rows, n), w_u (m, r), w_v (r, n), mask (r,) → (rows, m).
    """
    return _forward(x, w_u, w_v, mask)


def _fwd(x, w_u, w_v, mask):
    y = _forward(x, w_u, w_v, mask)
    return y, (x, w_u, w_v, mask)


def _bwd(res, dy):
    x, w_u, w_v, mask = res
    t = x @ w_v.T                       # (rows, r)
    u = t * mask[None, :]               # post-mask intermediate
    du = dy @ w_u                       # (rows, r)
    dmask = jnp.sum(du * t, axis=0)     # (r,) — the STE surrogate ∂L/∂m
    dt = du * mask[None, :]
    dx = dt @ w_v
    dw_u = dy.T @ u                     # (m, r)
    dw_v = dt.T @ x                     # (r, n)
    return dx, dw_u, dw_v, dmask


masked_lowrank.defvjp(_fwd, _bwd)
