"""Pallas kernel: causal self-attention core.

One grid step per packed (batch × head) index; the `(t, dh)` q/k/v slabs and
the `(t, t)` score tile stay VMEM-resident for the whole softmax — the TPU
analogue of a fused flash-attention block at the sequence lengths this repo
compiles (t ≤ 160 ⇒ score tile ≤ 100 KiB). interpret=True; backward via
custom_vjp with the standard softmax-attention gradients in jnp.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    q = q_ref[0]                       # (t, dh)
    k = k_ref[0]
    v = v_ref[0]
    t = q.shape[0]
    scores = jnp.dot(q, k.T) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal, scores, jnp.float32(-1e30))
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v)


def _forward(q, k, v, scale):
    bh, t, dh = q.shape
    spec = pl.BlockSpec((1, t, dh), lambda i: (i, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(bh,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, t, dh), q.dtype),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def causal_attention(q, k, v, scale):
    """softmax(q·kᵀ·scale + causal)·v over (bh, t, dh) packed heads."""
    return _forward(q, k, v, scale)


def _probs(q, k, scale):
    t = q.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, jnp.float32(-1e30))
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    return p / jnp.sum(p, axis=-1, keepdims=True)


def _fwd(q, k, v, scale):
    return _forward(q, k, v, scale), (q, k, v)


def _bwd(scale, res, dy):
    q, k, v = res
    p = _probs(q, k, scale)                                   # (bh, tq, tk)
    dv = jnp.einsum("bqk,bqd->bkd", p, dy)
    dp = jnp.einsum("bqd,bkd->bqk", dy, v)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("bqk,bkd->bqd", ds, k) * scale
    dk = jnp.einsum("bqk,bqd->bkd", ds, q) * scale
    return dq, dk, dv


causal_attention.defvjp(_fwd, _bwd)
