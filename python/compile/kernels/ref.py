"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every Pallas kernel in this package has a reference implementation here with
the exact same signature; pytest (python/tests/) asserts allclose between the
two across shapes/dtypes (hypothesis sweeps). The refs also serve as the
building blocks of the kernels' custom_vjp backward passes.
"""

import jax.numpy as jnp


def masked_lowrank(x, w_u, w_v, mask):
    """y = ((x @ W_vᵀ) ⊙ m) @ W_uᵀ — the masked low-rank linear (Eq. 8, R<1).

    Args:
      x:    (rows, n) input activations.
      w_u:  (m, r) left factor  (U·√Σ).
      w_v:  (r, n) right factor (√Σ·Vᵀ·S⁻¹).
      mask: (r,)   binary/probabilistic rank mask.

    Returns: (rows, m).
    """
    t = x @ w_v.T
    return (t * mask[None, :]) @ w_u.T


def rmsnorm(x, gain, eps=1e-6):
    """RMSNorm over the last dim: x / rms(x) * gain."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gain


def causal_attention(q, k, v, scale):
    """Causal self-attention core over packed heads.

    Args:
      q, k, v: (bh, t, dh) — batch×heads packed in the leading dim.
      scale:   scalar, usually 1/sqrt(dh).

    Returns: (bh, t, dh).
    """
    t = q.shape[1]
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    causal = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(causal[None, :, :], scores, jnp.float32(-1e30))
    p = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v)
