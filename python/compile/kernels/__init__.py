# L1: Pallas kernels for the paper's compute hot-spots.
#
# All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
# custom-calls); they lower to plain HLO inside the surrounding jitted model
# so the rust runtime sees a single executable. Each kernel carries a
# custom_vjp whose backward is expressed with the jnp reference math — the
# forward is the hot path that the TPU BlockSpec schedule is designed for,
# the backward only runs inside build-time-lowered training graphs.

from .masked_lowrank import masked_lowrank
from .rmsnorm import rmsnorm
from .attention import causal_attention

__all__ = ["masked_lowrank", "rmsnorm", "causal_attention"]
