"""Pallas kernel: RMSNorm over the last dim.

Row-blocked so each grid step normalizes a VMEM-resident `(brows, d)` slab;
the gain vector rides along broadcast. interpret=True (see package docstring);
backward via custom_vjp with the standard closed-form expressed in jnp.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-6


def _pick_block(dim, target):
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _kernel(x_ref, g_ref, o_ref):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = x * (1.0 / jnp.sqrt(ms + _EPS)) * g_ref[...][None, :]


def _forward(x, gain):
    rows, d = x.shape
    brows = _pick_block(rows, 256)
    return pl.pallas_call(
        _kernel,
        grid=(rows // brows,),
        in_specs=[
            pl.BlockSpec((brows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((brows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=True,
    )(x, gain)


@jax.custom_vjp
def rmsnorm(x, gain):
    """RMSNorm: x / sqrt(mean(x², -1) + eps) * gain. x (rows, d), gain (d,)."""
    return _forward(x, gain)


def _fwd(x, gain):
    return _forward(x, gain), (x, gain)


def _bwd(res, dy):
    x, gain = res
    d = x.shape[-1]
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    r = 1.0 / jnp.sqrt(ms + _EPS)
    dg = jnp.sum(dy * x * r, axis=0)
    dyg = dy * gain[None, :]
    dx = dyg * r - x * (r ** 3) * jnp.sum(dyg * x, axis=-1, keepdims=True) / d
    return dx, dg


rmsnorm.defvjp(_fwd, _bwd)
