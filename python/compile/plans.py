"""CompressionPlan schema mirror (PR 5) — importable WITHOUT jax.

The rust side (`rust/src/compress/plan.rs`) writes versioned plan JSON:

  {
   "schema_version": 2,
   "spec": "ara@0.8?quant=int8",    # registry method spec
   "method": "ara", "label": "ARA",
   "target": 0.8, "achieved": 0.7931,
   "seed": 7,                        # null for data-free methods
   "quant": {"bits": 8, "group": 32},  # v2: null for pure-f32 plans
   "scale": {"alloc_samples": 96, "alloc_epochs": 10},
   "wall_ms": 1234.5,
   "allocation": {"name": ..., "modules": {...}}   # the legacy schema
  }

Schema v2 added the optional `quant` recipe (top-level mirror of
`allocation.quant`); v1 files load unchanged with no recipe.

`aot.py` resolves serving allocations through `load_alloc_file`, so a
plan file dropped into configs/allocations/ specializes serving exactly
like a legacy bare-Allocation file. The CLI `--roundtrip` mode re-emits a
plan through this parser; rust's tests/registry.rs pins the cross-language
round-trip bit-for-bit.
"""

import json
import sys

SCHEMA_VERSION = 2

PLAN_KEYS = (
    "schema_version", "spec", "method", "label", "target", "achieved",
    "seed", "scale", "wall_ms", "allocation",
)

# v2 additions: present in fresh files, absent in v1 files — validated
# when present, never required.
OPTIONAL_KEYS = ("quant",)


def is_plan(doc):
    """A plan carries schema_version; a legacy bare Allocation does not."""
    return isinstance(doc, dict) and "schema_version" in doc


def validate_plan(doc):
    """Check the plan shape; raises ValueError naming what is wrong."""
    version = doc.get("schema_version")
    if not isinstance(version, int) or version < 0:
        raise ValueError(f"bad schema_version {version!r}")
    if version > SCHEMA_VERSION:
        raise ValueError(
            f"plan schema_version {version} newer than supported {SCHEMA_VERSION}")
    for key in PLAN_KEYS:
        if key not in doc:
            raise ValueError(f"plan missing key `{key}`")
    alloc = doc["allocation"]
    if "name" not in alloc or "modules" not in alloc:
        raise ValueError("plan allocation missing name/modules")
    scale = doc["scale"]
    for key in ("alloc_samples", "alloc_epochs"):
        if key not in scale:
            raise ValueError(f"plan scale missing `{key}`")
    quant = doc.get("quant")
    if quant is not None:
        for key in ("bits", "group"):
            if not isinstance(quant.get(key), int) or quant[key] <= 0:
                raise ValueError(f"plan quant has bad `{key}`: {quant!r}")
    return doc


def load_alloc_file(path):
    """Load an allocation from a plan OR legacy bare-Allocation file.

    Returns (allocation_dict, plan_or_None)."""
    with open(path) as f:
        doc = json.load(f)
    if is_plan(doc):
        validate_plan(doc)
        return doc["allocation"], doc
    return doc, None


def dump_plan(plan, path):
    """Write a plan compactly (matching the rust serializer's key order)."""
    validate_plan(plan)
    keys = [k for k in PLAN_KEYS]
    if "quant" in plan:  # v2: keep rust's key order (after seed)
        keys.insert(keys.index("seed") + 1, "quant")
    ordered = {k: plan[k] for k in keys}
    with open(path, "w") as f:
        json.dump(ordered, f, separators=(",", ":"))


def main(argv):
    if len(argv) == 3 and argv[0] == "--roundtrip":
        alloc, plan = load_alloc_file(argv[1])
        if plan is None:
            raise SystemExit(f"{argv[1]} is a legacy allocation, not a plan")
        dump_plan(plan, argv[2])
        print(f"roundtripped {argv[1]} -> {argv[2]} "
              f"(schema v{plan['schema_version']}, spec {plan['spec']}, "
              f"{len(alloc['modules'])} modules)")
        return 0
    print("usage: plans.py --roundtrip <plan.json> <out.json>", file=sys.stderr)
    return 2


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
