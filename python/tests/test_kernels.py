"""L1 correctness: each Pallas kernel vs its pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/dtypes; gradients (custom_vjp backward) are checked
against jax.grad of the reference — these are the surrogates the rust STE
path consumes, so they are the core correctness signal of the repo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import masked_lowrank, rmsnorm, causal_attention
from compile.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


# ---------------------------------------------------------------------------
# masked_lowrank
# ---------------------------------------------------------------------------

@given(rows=st.integers(1, 33), m=st.integers(1, 40), n=st.integers(1, 40),
       seed=st.integers(0, 2**31 - 1))
def test_masked_lowrank_matches_ref(rows, m, n, seed):
    rng = np.random.default_rng(seed)
    r = min(m, n)
    x, wu, wv = _arr(rng, rows, n), _arr(rng, m, r), _arr(rng, r, n)
    mask = jnp.asarray((rng.random(r) > 0.5).astype(np.float32))
    got = masked_lowrank(x, wu, wv, mask)
    want = ref.masked_lowrank(x, wu, wv, mask)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_masked_lowrank_probabilistic_mask(seed):
    """Non-binary masks (the probabilistic p of Eq. 2) must also match."""
    rng = np.random.default_rng(seed)
    x, wu, wv = _arr(rng, 8, 24), _arr(rng, 16, 16), _arr(rng, 16, 24)
    mask = jnp.asarray(rng.random(16).astype(np.float32))
    np.testing.assert_allclose(masked_lowrank(x, wu, wv, mask),
                               ref.masked_lowrank(x, wu, wv, mask),
                               rtol=2e-4, atol=2e-4)


def test_masked_lowrank_zero_mask_zero_output(rng):
    x, wu, wv = _arr(rng, 4, 8), _arr(rng, 8, 8), _arr(rng, 8, 8)
    out = masked_lowrank(x, wu, wv, jnp.zeros(8))
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("wrt", [0, 1, 2, 3])
def test_masked_lowrank_grads_match_ref(rng, wrt):
    x, wu, wv = _arr(rng, 6, 12), _arr(rng, 10, 12), _arr(rng, 12, 12)
    mask = jnp.asarray(rng.random(12).astype(np.float32))
    args = [x, wu, wv, mask]

    def f_k(a):
        args2 = list(args); args2[wrt] = a
        return jnp.sum(jnp.sin(masked_lowrank(*args2)))

    def f_r(a):
        args2 = list(args); args2[wrt] = a
        return jnp.sum(jnp.sin(ref.masked_lowrank(*args2)))

    gk = jax.grad(f_k)(args[wrt])
    gr = jax.grad(f_r)(args[wrt])
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-3)


def test_masked_lowrank_mask_grad_is_ste_surrogate(rng):
    """∂L/∂m_i = Σ_rows (dy·W_u)_i · t_i — the quantity rust chains via M."""
    x, wu, wv = _arr(rng, 5, 8), _arr(rng, 8, 8), _arr(rng, 8, 8)
    mask = jnp.ones(8)
    g = jax.grad(lambda mm: 0.5 * jnp.sum(masked_lowrank(x, wu, wv, mm) ** 2))(mask)
    t = np.asarray(x @ wv.T)
    y = np.asarray(ref.masked_lowrank(x, wu, wv, mask))
    du = y @ np.asarray(wu)
    np.testing.assert_allclose(g, np.sum(du * t, axis=0), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@given(rows=st.integers(1, 64), d=st.integers(2, 48),
       seed=st.integers(0, 2**31 - 1))
def test_rmsnorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x, g = _arr(rng, rows, d), _arr(rng, d)
    np.testing.assert_allclose(rmsnorm(x, g), ref.rmsnorm(x, g),
                               rtol=1e-5, atol=1e-5)


def test_rmsnorm_grads_match_ref(rng):
    x, g = _arr(rng, 9, 16), _arr(rng, 16)
    for wrt in (0, 1):
        def f(a, impl):
            args = [x, g]; args[wrt] = a
            return jnp.sum(jnp.cos(impl(*args)))
        gk = jax.grad(lambda a: f(a, rmsnorm))( [x, g][wrt])
        gr = jax.grad(lambda a: f(a, ref.rmsnorm))([x, g][wrt])
        np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


def test_rmsnorm_scale_invariance(rng):
    """rmsnorm(c·x) == rmsnorm(x) for c>0 (up to eps effects)."""
    x, g = _arr(rng, 4, 32), _arr(rng, 32)
    a, b = rmsnorm(x, g), rmsnorm(3.7 * x, g)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# causal attention
# ---------------------------------------------------------------------------

@given(bh=st.integers(1, 6), t=st.integers(1, 24), dh=st.integers(2, 16),
       seed=st.integers(0, 2**31 - 1))
def test_attention_matches_ref(bh, t, dh, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _arr(rng, bh, t, dh), _arr(rng, bh, t, dh), _arr(rng, bh, t, dh)
    s = dh ** -0.5
    np.testing.assert_allclose(causal_attention(q, k, v, s),
                               ref.causal_attention(q, k, v, s),
                               rtol=1e-4, atol=1e-4)


def test_attention_is_causal(rng):
    """Output at position i must not depend on positions > i."""
    q, k, v = _arr(rng, 2, 10, 8), _arr(rng, 2, 10, 8), _arr(rng, 2, 10, 8)
    out1 = np.asarray(causal_attention(q, k, v, 0.3))
    k2 = k.at[:, 7:, :].set(99.0)
    v2 = v.at[:, 7:, :].set(-99.0)
    out2 = np.asarray(causal_attention(q, k2, v2, 0.3))
    np.testing.assert_allclose(out1[:, :7], out2[:, :7], rtol=1e-5, atol=1e-5)


def test_attention_grads_match_ref(rng):
    q, k, v = _arr(rng, 3, 8, 6), _arr(rng, 3, 8, 6), _arr(rng, 3, 8, 6)
    for wrt in range(3):
        def f(a, impl):
            args = [q, k, v]; args[wrt] = a
            return jnp.sum(impl(*args, 0.41) ** 2)
        gk = jax.grad(lambda a: f(a, causal_attention))([q, k, v][wrt])
        gr = jax.grad(lambda a: f(a, ref.causal_attention))([q, k, v][wrt])
        np.testing.assert_allclose(gk, gr, rtol=2e-4, atol=2e-4)
