import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


MICRO = {
    "name": "test-llama", "family": "llama",
    "d_model": 32, "n_layers": 2, "n_heads": 2, "n_kv_heads": 2,
    "d_ff": 80, "vocab": 64, "max_seq": 32, "rope_theta": 10000.0,
    "batch_train": 2, "seq_train": 16, "batch_eval": 2, "seq_eval": 16,
    "lora_rank": 4, "serving": True, "decode_batches": [2],
    "prefill_len": 8, "max_decode_seq": 24,
}

MICRO_QWEN = dict(MICRO, name="test-qwen", family="qwen", n_heads=4,
                  n_kv_heads=2)


@pytest.fixture
def cfg():
    return dict(MICRO)


@pytest.fixture
def cfg_qwen():
    return dict(MICRO_QWEN)
