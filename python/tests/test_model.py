"""L2 correctness: model graphs — shapes, dense↔factored equivalence, grads.

The key invariant for the whole repo is `test_factored_full_mask_equals_dense`:
the R ≥ 1 branch of Eq. 8 is executed as an all-ones mask over the full-rank
SVD factorization, so the factored path with identity-equivalent factors must
reproduce the dense forward bit-for-bit up to f32 accumulation error.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


def init_dense(cfg, rng, scale=0.05):
    out = []
    for name, shape in M.spec_dense(cfg):
        a = rng.normal(size=shape).astype(np.float32) * scale
        if name.endswith(("ln1", "ln2", "norm_f", "qnorm", "knorm")):
            a = np.ones(shape, np.float32)
        out.append((name, jnp.asarray(a)))
    return dict(out)


def factored_from_dense(cfg, dense, rng):
    """Exact full-rank factorization W = W_u·W_v via numpy SVD."""
    params = {k: v for k, v in dense.items()
              if k not in dict(M.module_dims(cfg))}
    for name, (m, n) in M.module_dims(cfg):
        w = np.asarray(dense[name]).astype(np.float64)
        u, s, vt = np.linalg.svd(w, full_matrices=False)
        r = min(m, n)
        wu = (u * np.sqrt(s)[None, :]).astype(np.float32)
        wv = (np.sqrt(s)[:, None] * vt).astype(np.float32)
        params[name + ".u"] = jnp.asarray(wu)
        params[name + ".v"] = jnp.asarray(wv)
        params["mask:" + name] = jnp.ones(r, jnp.float32)
    return params


def batch(cfg, rng, b=None, t=None):
    b = b or cfg["batch_eval"]
    t = t or cfg["seq_eval"]
    toks = rng.integers(0, cfg["vocab"], size=(b, t)).astype(np.int32)
    tgts = rng.integers(0, cfg["vocab"], size=(b, t)).astype(np.int32)
    return jnp.asarray(toks), jnp.asarray(tgts)


@pytest.mark.parametrize("fam", ["cfg", "cfg_qwen"])
def test_forward_shapes(fam, request, rng):
    cfg = request.getfixturevalue(fam)
    params = init_dense(cfg, rng)
    toks, _ = batch(cfg, rng)
    logits = M.forward(cfg, params, toks)
    assert logits.shape == (cfg["batch_eval"], cfg["seq_eval"], cfg["vocab"])
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("fam", ["cfg", "cfg_qwen"])
def test_factored_full_mask_equals_dense(fam, request, rng):
    cfg = request.getfixturevalue(fam)
    dense = init_dense(cfg, rng)
    fact = factored_from_dense(cfg, dense, rng)
    toks, tgts = batch(cfg, rng)
    nll_d = np.asarray(M.nll_tokens(cfg, dense, toks, tgts))
    nll_f = np.asarray(M.nll_tokens(cfg, fact, toks, tgts))
    np.testing.assert_allclose(nll_f, nll_d, rtol=5e-3, atol=5e-3)


def test_truncation_degrades_gracefully(cfg, rng):
    """Masking the smallest singular values must change NLL only mildly;
    masking the largest must hurt far more (monotonicity rationale, Sec 3.2)."""
    dense = init_dense(cfg, rng)
    fact = factored_from_dense(cfg, dense, rng)
    toks, tgts = batch(cfg, rng)
    base = float(jnp.mean(M.nll_tokens(cfg, fact, toks, tgts)))

    drop_small = dict(fact)
    drop_large = dict(fact)
    for name, (m, n) in M.module_dims(cfg):
        r = min(m, n)
        keep = int(0.8 * r)
        ms = np.ones(r, np.float32); ms[keep:] = 0.0
        ml = np.ones(r, np.float32); ml[: r - keep] = 0.0
        drop_small["mask:" + name] = jnp.asarray(ms)
        drop_large["mask:" + name] = jnp.asarray(ml)
    small = float(jnp.mean(M.nll_tokens(cfg, drop_small, toks, tgts)))
    large = float(jnp.mean(M.nll_tokens(cfg, drop_large, toks, tgts)))
    assert abs(small - base) < abs(large - base)


def test_train_step_outputs(cfg, rng):
    fn, spec, outs = M.make_train_step(cfg)
    arrays = []
    dense = init_dense(cfg, rng)
    toks, tgts = batch(cfg, rng, cfg["batch_train"], cfg["seq_train"])
    for name, shape, dt in spec:
        if name == "tokens":
            arrays.append(toks)
        elif name == "targets":
            arrays.append(tgts)
        else:
            arrays.append(dense[name])
    res = fn(*arrays)
    assert len(res) == len(outs)
    assert np.isfinite(float(res[0]))
    # grads nonzero for embed and at least one weight
    assert float(jnp.sum(jnp.abs(res[1]))) > 0


def test_mask_fwd_grad_sign(cfg, rng):
    """Enabling more rank should (locally) not increase loss for top values:
    grads w.r.t. enabled top components exist and are finite."""
    fn, spec, outs = M.make_mask_fwd_grad(cfg)
    dense = init_dense(cfg, rng)
    fact = factored_from_dense(cfg, dense, rng)
    toks, tgts = batch(cfg, rng)
    arrays = []
    for name, shape, dt in spec:
        if name == "tokens":
            arrays.append(toks)
        elif name == "targets":
            arrays.append(tgts)
        else:
            arrays.append(fact[name])
    res = fn(*arrays)
    assert len(res) == 1 + len(M.mask_names(cfg))
    for g in res[1:]:
        assert np.all(np.isfinite(np.asarray(g)))


def test_decode_matches_prefill_continuation(cfg, rng):
    """Greedy scoring: prefill(P tokens) then decode step must produce the
    same next-token logits as a full forward over P+1 tokens."""
    alloc = {"name": "dense",
             "modules": {n: {"dense": True} for n, _ in M.module_dims(cfg)}}
    b, P = 2, cfg["prefill_len"]
    dense = init_dense(cfg, rng)
    toks = rng.integers(2, cfg["vocab"], size=(b, P + 1)).astype(np.int32)

    pf, pf_spec, _ = M.make_prefill(cfg, alloc, b)
    arrays = [dense[n] if n != "tokens" else jnp.asarray(toks[:, :P])
              for n, _, _ in pf_spec]
    pf_out = pf(*arrays)
    logits_p, caches = pf_out[0], list(pf_out[1:])

    # reference: full forward logits at position P-1
    full = np.asarray(M.forward(cfg, dense, jnp.asarray(toks)))
    np.testing.assert_allclose(np.asarray(logits_p), full[:, P - 1],
                               rtol=2e-3, atol=2e-3)

    dc, dc_spec, _ = M.make_decode(cfg, alloc, b)
    dargs = []
    ci = 0
    for n, _, _ in dc_spec:
        if n.startswith(("kcache", "vcache")):
            dargs.append(caches[ci]); ci += 1
        elif n == "tokens":
            dargs.append(jnp.asarray(toks[:, P]))
        elif n == "lens":
            dargs.append(jnp.full((b,), P, jnp.int32))
        else:
            dargs.append(dense[n])
    dc_out = dc(*dargs)
    np.testing.assert_allclose(np.asarray(dc_out[0]), full[:, P],
                               rtol=2e-3, atol=2e-3)


def test_lora_step_grads(cfg, rng):
    fn, spec, outs = M.make_lora_step(cfg)
    dense = init_dense(cfg, rng)
    fact = factored_from_dense(cfg, dense, rng)
    toks, tgts = batch(cfg, rng, cfg["batch_train"], cfg["seq_train"])
    arrays = []
    for name, shape, dt in spec:
        if name == "tokens":
            arrays.append(toks)
        elif name == "targets":
            arrays.append(tgts)
        elif name.startswith("lora_a:"):
            arrays.append(jnp.asarray(
                rng.normal(size=shape).astype(np.float32) * 0.05))
        elif name.startswith("lora_b:"):
            arrays.append(jnp.zeros(shape, jnp.float32))
        else:
            arrays.append(fact[name])
    res = fn(*arrays)
    assert np.isfinite(float(res[0]))
    # B initialized to zero ⇒ dA must be zero, dB nonzero (standard LoRA).
    names = outs[1:]
    for nm, g in zip(names, res[1:]):
        if nm.startswith("grad:lora_a:"):
            np.testing.assert_allclose(np.asarray(g), 0.0, atol=1e-6)
    db_total = sum(float(jnp.sum(jnp.abs(g)))
                   for nm, g in zip(names, res[1:])
                   if nm.startswith("grad:lora_b:"))
    assert db_total > 0
